//! # BaPipe — balanced pipeline parallelism for DNN training
//!
//! Reproduction of *"BaPipe: Exploration of Balanced Pipeline Parallelism
//! for DNN Training"* (Zhao et al., 2020) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: automatic exploration
//!   of pipeline *scheduling* ([`schedule`]) and *balanced partition*
//!   ([`partition`]) by the typed, parallel [`planner`] (with [`explorer`]
//!   as its seed-compatible façade), a discrete-event cluster simulator
//!   ([`sim`]), and — behind the `pjrt` cargo feature — a real
//!   multi-threaded pipeline training engine (`pipeline`) executing
//!   AOT-compiled XLA stage programs via `runtime`.
//! * **L2 (python/compile/model.py)** — JAX transformer-LM stage graphs
//!   (fwd / bwd-with-recompute / adam / init), lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot spots, verified against a pure-jnp oracle.
//!
//! Python never runs on the training path: `make artifacts` produces
//! `artifacts/<model>/*.hlo.txt` + `manifest.json`, and the rust binary is
//! self-contained afterwards. Without the `pjrt` feature (the default),
//! the crate builds with no XLA toolchain at all — the planner, simulator
//! and every paper-table bench run anywhere.
//!
//! ## Quick tour
//!
//! ```no_run
//! use bapipe::{cluster, model, planner, profile};
//!
//! // 1. Describe the workload and the cluster.
//! let net = model::zoo::vgg16(224);
//! let cl = cluster::presets::v100_cluster(4);
//! // 2. Profile analytically (or measure real stage executables). The
//! //    partition hot path runs on `profile::RangeCost` prefix tables —
//! //    O(1) per layer-range cost probe, one table set per cluster view
//! //    shared across every micro-batch size.
//! let prof = profile::analytical::profile(&net, &cl);
//! // 3. Let BaPipe explore schedule x partition x micro-batching —
//! //    prefix-table + monotone-crossing partition DPs (O(N·C·log C)
//! //    against `dp_optimal_reference`, the retained seed oracle),
//! //    pruned by analytical lower bounds, phases A (partition DPs) and
//! //    B (trace-free SoA DES) both fanned out over 4 worker threads —
//! //    phase B on pooled `sim::batch::FamilySim` simulators that batch
//! //    a family's whole M grid through one arena and survive across
//! //    the grid pass and every adaptive M bisection round around the
//! //    incumbent. `planner::store` persists the partition cache across
//! //    invocations (`bapipe explore --plan-cache`). On heterogeneous
//! //    clusters `permute_devices` widens the space with device
//! //    orderings: exhaustive up to 8 devices, and past that the
//! //    `planner::orders` neighbourhood search (`order_search`) —
//! //    seeded heuristic layouts hill-climbed under a probe budget.
//! //    Memory is a *simulated* quantity throughout: phase B prices each
//! //    stage's DES in-flight high-water mark through the same
//! //    `partition::memfit::StageBytes` the feasibility check used, and
//! //    `pareto`/`recompute` widen the space with the memory-scalable
//! //    2BW schedule (double-buffered weight versions) and activation
//! //    recomputation, keeping the (epoch time × peak memory) Pareto
//! //    front in the plan.
//! let opts = planner::Options { jobs: 4, adaptive_m: true, pareto: true, ..Default::default() };
//! let plan = planner::explore(&net, &cl, &prof, &opts);
//! println!("{}", plan.summary());
//! // 4. The typed report is serializable: this is `bapipe explore --emit`.
//! std::fs::write("plan.json", plan.to_json().to_string_pretty()).unwrap();
//! // 5. Compare two artifacts (`bapipe plan diff old.json new.json`).
//! let diff = planner::diff::compare(&plan, &plan);
//! assert!(diff.same_choice);
//! // 6. Elastic clusters: replay a fault-injection scenario against the
//! //    incumbent plan (`bapipe replan --plan plan.json --scenario s.json`).
//! //    `planner::elastic` warm-starts every replan — the incumbent is
//! //    re-evaluated on the mutated cluster to seed the branch-and-bound,
//! //    the order portfolio is seeded with the surviving permutation, and
//! //    `EvalCache` views whose device sequence survived are salvaged —
//! //    and prices each plan switch as migration bytes of weights +
//! //    optimizer state. If a loss makes every plain-schedule partition
//! //    memfit-infeasible, the explorer widens to the recompute/2BW axes
//! //    before falling back to data parallelism.
//! use bapipe::cluster::mutate::{ClusterEvent, Scenario};
//! let scenario =
//!     Scenario::scripted("outage", vec![ClusterEvent::DeviceLoss { device: 1 }]);
//! let run = planner::elastic::run_scenario(&net, &cl, &prof, &plan, &scenario, &opts).unwrap();
//! println!("{}", run.render());
//! // 7. Or close the loop live, with no script at all: `cluster::detect`
//! //    drift-detects over per-device/per-link timing samples (windowed
//! //    median + EWMA, enter/exit hysteresis + dwell — bounded jitter
//! //    emits nothing, a persistent step emits exactly one event), each
//! //    detection carries its epoch position in micro-batches, the
//! //    challenger's state transfers are scheduled into the draining
//! //    pipeline's bubbles (`planner::migrate` — overlapped under 2BW
//! //    shadow weight versions, drain-and-copy otherwise), and
//! //    `planner::elastic::amortize_switch` keeps the degraded incumbent
//! //    when a late-epoch switch cannot pay for its migration stall
//! //    before the epoch boundary (`bapipe replan --detect samples.json`).
//! use bapipe::cluster::detect::{detect, DetectorConfig, SampleStream};
//! let doc = bapipe::util::json::Json::parse(
//!     &std::fs::read_to_string("samples.json").unwrap()).unwrap();
//! let stream = SampleStream::from_json(&doc).unwrap();
//! let detection = detect(&stream, &DetectorConfig::default()).unwrap();
//! let live = detection.to_scenario(&stream);
//! let run = planner::elastic::run_scenario(&net, &cl, &prof, &plan, &live, &opts).unwrap();
//! println!("{}", run.render());
//! // 8. Certify without simulating: `verify` statically proves every
//! //    generated stage program dependency-sound (fwd before bwd per
//! //    micro-batch, FIFO transfers, no send/recv deadlock cycle),
//! //    certifies the schedule's staleness bound (2BW keeps exactly one
//! //    shadow weight version; 1F1B keeps none) and re-derives each
//! //    stage's peak memory from program text — then audits the emitted
//! //    artifact itself (`bapipe check plan.json`, exit 0/1/2 =
//! //    clean/warnings/violations). Debug builds run the same gate on
//! //    every candidate before it reaches the DES.
//! let gate = bapipe::verify::check_program(
//!     bapipe::schedule::ScheduleKind::TwoBW, 4, 8);
//! assert!(gate.is_clean(), "{}", gate.render("2bw 4x8"));
//! let audit = bapipe::verify::plan_audit(&plan, Some(&cl));
//! assert_eq!(audit.exit_code(), 0);
//! ```
//!
//! The simulator itself has three entry points: `sim::engine::simulate_full`
//! (event traces for timelines and figures), the allocation-free
//! `sim::engine::simulate_fast` over a reusable `sim::engine::SimArena`,
//! and `sim::batch::FamilySim` — table-free batched passes over a
//! candidate family plus incremental re-simulation of perturbed specs
//! from a checkpoint (the order search's probe path). All are bit-exact
//! with each other and with the retained seed oracle
//! `sim::engine::simulate_reference`.
#![deny(missing_docs)]
// The crate is pure safe Rust end to end — the simulator, planner and
// verifier never need raw pointers, FFI or unchecked indexing, so lock
// that property in rather than merely observing it.
#![forbid(unsafe_code)]
// Ratcheted lint wall: each of these is verified absent from the tree
// and denied so it cannot creep back in. Debug/stub macros never belong
// in committed planner code, `std::process::exit` would skip arena /
// cache destructors (the CLI exits through `main`'s return path except
// for the explicit `bapipe check` exit-code contract, which lives in
// the binary crate, not here), and `mem::forget` would silently leak
// pooled simulator arenas.
#![deny(clippy::dbg_macro)]
#![deny(clippy::todo)]
#![deny(clippy::unimplemented)]
#![deny(clippy::exit)]
#![deny(clippy::mem_forget)]
// Documented allowlist — pedantic lints we deliberately do NOT ratchet:
// * `clippy::too_many_arguments` (below): the cost-model layers pass
//   (profile, cluster, partition, micro, m) tuples through free
//   functions by design — the argument-count lint would force noise
//   structs on a hot, internally-consistent API.
// * print lints stay off: `util::logging`, the benches and the report
//   renderers talk to stdout/stderr on purpose.
#![allow(clippy::too_many_arguments)]

pub mod cluster;
pub mod collective;
pub mod config;
pub mod data;
pub mod explorer;
pub mod metrics;
pub mod model;
pub mod partition;
#[cfg(feature = "pjrt")]
pub mod pipeline;
pub mod planner;
pub mod profile;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod util;
pub mod verify;

/// Crate-wide result type (thin alias over [`anyhow::Result`]).
pub type Result<T> = anyhow::Result<T>;
