//! GNMT (Wu et al. 2016) — LSTM encoder-decoder with attention, the
//! sequence workload of Tables 3–4. `gnmt_l(L)` builds the paper's GNMT-L
//! scaling family: L/2 encoder + L/2 decoder layers; calibrated so the
//! parameter counts match Table 4 ((32, 445.6M) … (158, 1.78B) within a
//! few percent).

use crate::model::costs::*;
use crate::model::{Layer, LayerKind, Network};

/// Build GNMT with `total_layers` LSTM layers split evenly between encoder
/// and decoder, hidden size `h`, vocabulary `vocab`, sequence length `seq`.
///
/// Structure (following the GNMT paper):
/// * source embedding `vocab×h`
/// * encoder: layer 1 bidirectional (2× params, output 2h), layer 2 input
///   2h, remaining layers h→h
/// * additive attention (`2h² + h` params)
/// * target embedding `vocab×h`
/// * decoder: every layer input `2h` (hidden + attention context)
/// * output projection `h → vocab`
pub fn gnmt(total_layers: u64, h: u64, vocab: u64, seq: u64) -> Network {
    assert!(total_layers >= 2 && total_layers % 2 == 0, "gnmt needs an even layer count ≥ 2");
    let n_enc = total_layers / 2;
    let n_dec = total_layers / 2;
    let mut layers = Vec::new();

    // Source embedding. Lookup is memory-bound: ~1 FLOP/element copied.
    layers.push(Layer::new(
        "src_embed",
        LayerKind::Embedding,
        act_flops(seq * h, 1.0),
        vocab * h,
        seq * h,
    ));

    // Encoder.
    for i in 0..n_enc {
        let (name, params, flops, out_elems) = if i == 0 {
            // bidirectional: 2 directions of h→h
            (
                "enc_bilstm1".to_string(),
                2 * lstm_params(h, h),
                2.0 * lstm_flops(h, h, seq),
                seq * 2 * h,
            )
        } else if i == 1 {
            // consumes the 2h bidirectional output
            ("enc_lstm2".to_string(), lstm_params(2 * h, h), lstm_flops(2 * h, h, seq), seq * h)
        } else {
            (format!("enc_lstm{}", i + 1), lstm_params(h, h), lstm_flops(h, h, seq), seq * h)
        };
        layers.push(Layer::new(name, LayerKind::Lstm, flops, params, out_elems));
    }

    // Attention (additive): scored once per decoder step over seq keys.
    layers.push(Layer::new(
        "attention",
        LayerKind::Attention,
        2.0 * (2 * h * h * seq) as f64 + 2.0 * (seq * seq * h) as f64,
        2 * h * h + h,
        seq * h,
    ));

    // Target embedding.
    layers.push(Layer::new(
        "tgt_embed",
        LayerKind::Embedding,
        act_flops(seq * h, 1.0),
        vocab * h,
        seq * h,
    ));

    // Decoder: every layer input 2h (prev hidden/emb concat context).
    for i in 0..n_dec {
        layers.push(Layer::new(
            format!("dec_lstm{}", i + 1),
            LayerKind::Lstm,
            lstm_flops(2 * h, h, seq),
            lstm_params(2 * h, h),
            seq * h,
        ));
    }

    // Output projection + softmax.
    layers.push(Layer::new(
        "proj",
        LayerKind::Linear,
        linear_flops(h, vocab, seq),
        linear_params(h, vocab),
        seq * vocab,
    ));
    layers.push(Layer::new(
        "softmax",
        LayerKind::Softmax,
        act_flops(seq * vocab, 5.0),
        0,
        seq * vocab,
    ));

    Network::new(format!("gnmt{total_layers}"), layers, seq)
}

/// The Table-4 scaling family: GNMT-L with `l` total LSTM layers
/// (h=1024, vocab=32k, seq=50).
pub fn gnmt_l(l: u64) -> Network {
    let mut n = gnmt(l, 1024, 32000, 50);
    n.name = format!("gnmt-l{l}");
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 4 calibration: the paper's (L, W) pairs.
    #[test]
    fn table4_param_calibration() {
        for (l, w) in [(32u64, 445.6e6), (42, 550.6e6), (60, 739.5e6), (74, 886.4e6)] {
            let n = gnmt_l(l);
            let p = n.total_params() as f64;
            let rel = (p - w).abs() / w;
            assert!(rel < 0.05, "gnmt-l{l}: params {p:.3e} vs paper {w:.3e} (rel {rel:.3})");
        }
    }

    #[test]
    fn table4_large_sizes() {
        for (l, w) in [(118u64, 1.35e9), (158, 1.78e9)] {
            let n = gnmt_l(l);
            let p = n.total_params() as f64;
            let rel = (p - w).abs() / w;
            assert!(rel < 0.06, "gnmt-l{l}: params {p:.3e} vs paper {w:.3e} (rel {rel:.3})");
        }
    }

    #[test]
    fn structure() {
        let n = gnmt(8, 1024, 32000, 50);
        // embed + 4 enc + attn + embed + 4 dec + proj + softmax = 13
        assert_eq!(n.len(), 13);
        assert!(n.layers.iter().any(|l| l.name == "enc_bilstm1"));
        assert!(n.layers.iter().any(|l| l.name == "dec_lstm4"));
    }

    #[test]
    #[should_panic(expected = "even layer count")]
    fn odd_layers_rejected() {
        gnmt(7, 1024, 32000, 50);
    }

    #[test]
    fn params_grow_linearly_in_l() {
        let d = gnmt_l(34).total_params() - gnmt_l(32).total_params();
        let d2 = gnmt_l(66).total_params() - gnmt_l(64).total_params();
        assert_eq!(d, d2, "constant per-layer-pair increment");
    }
}
