//! Decoder-only Transformer LM — the workload the **real** training engine
//! runs end-to-end (L2 lowers exactly this structure to per-stage HLO).
//! The rust-side cost IR here must stay consistent with
//! `python/compile/model.py`; the manifest round-trip test checks that.

use crate::model::costs::*;
use crate::model::{Layer, LayerKind, Network};

/// Transformer LM hyper-parameters (mirrors `python/compile/model.py`).
#[derive(Debug, Clone, PartialEq)]
pub struct TransformerCfg {
    /// Model (residual-stream) dimension.
    pub d_model: u64,
    /// Number of transformer blocks.
    pub n_layers: u64,
    /// Attention heads.
    pub n_heads: u64,
    /// Vocabulary size.
    pub vocab: u64,
    /// Sequence length.
    pub seq: u64,
}

impl TransformerCfg {
    /// ~10M-param config — the default e2e loss-curve run (1 CPU core).
    pub fn lm10m() -> Self {
        Self { d_model: 256, n_layers: 8, n_heads: 8, vocab: 4096, seq: 64 }
    }

    /// ~100M-param config — paper-scale validation (fewer steps on CPU).
    pub fn lm100m() -> Self {
        Self { d_model: 768, n_layers: 12, n_heads: 12, vocab: 8192, seq: 64 }
    }

    /// ~1M smoke config for integration tests.
    pub fn lm1m() -> Self {
        Self { d_model: 128, n_layers: 4, n_heads: 4, vocab: 512, seq: 32 }
    }

    /// Exact parameter count (embeddings + blocks + final norm; the LM
    /// head shares the embedding matrix, matching the python model).
    pub fn param_count(&self) -> u64 {
        let d = self.d_model;
        let embed = self.vocab * d + self.seq * d;
        let per_block = attention_params(d) + mlp_params(d) + 2 * norm_params(d);
        embed + self.n_layers * per_block + norm_params(d)
    }
}

/// Build the cost-model view of the transformer LM.
pub fn transformer_lm(cfg: &TransformerCfg) -> Network {
    let d = cfg.d_model;
    let s = cfg.seq;
    let mut layers = Vec::new();
    layers.push(Layer::new(
        "embed",
        LayerKind::Embedding,
        act_flops(s * d, 1.0),
        cfg.vocab * d + s * d,
        s * d,
    ));
    for b in 0..cfg.n_layers {
        // One block = ln1 + attention + ln2 + mlp, flattened; cuts only
        // after the complete block (residual stream crosses sub-layers).
        layers.push(
            Layer::new(
                format!("blk{b}_ln1"),
                LayerKind::Norm,
                norm_flops(s * d),
                norm_params(d),
                s * d,
            )
            .no_cut(),
        );
        layers.push(
            Layer::new(
                format!("blk{b}_attn"),
                LayerKind::Attention,
                attention_flops(d, s),
                attention_params(d),
                s * d,
            )
            .no_cut(),
        );
        layers.push(
            Layer::new(
                format!("blk{b}_ln2"),
                LayerKind::Norm,
                norm_flops(s * d),
                norm_params(d),
                s * d,
            )
            .no_cut(),
        );
        layers.push(Layer::new(
            format!("blk{b}_mlp"),
            LayerKind::Linear,
            mlp_flops(d, s),
            mlp_params(d),
            s * d,
        ));
    }
    layers.push(Layer::new("ln_f", LayerKind::Norm, norm_flops(s * d), norm_params(d), s * d));
    layers.push(Layer::new(
        "lm_head",
        LayerKind::Linear,
        linear_flops(d, cfg.vocab, s),
        0, // tied to embedding
        s * cfg.vocab,
    ));
    layers.push(Layer::new(
        "loss",
        LayerKind::Softmax,
        act_flops(s * cfg.vocab, 5.0),
        0,
        1,
    ));
    Network::new(
        format!("lm-d{}-l{}", cfg.d_model, cfg.n_layers),
        layers,
        s, // token ids
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm10m_is_about_10m() {
        let p = TransformerCfg::lm10m().param_count() as f64;
        assert!(p > 6e6 && p < 14e6, "lm10m params {p}");
    }

    #[test]
    fn lm100m_is_about_100m() {
        let p = TransformerCfg::lm100m().param_count() as f64;
        assert!(p > 85e6 && p < 120e6, "lm100m params {p}");
    }

    #[test]
    fn network_params_match_cfg_count() {
        let cfg = TransformerCfg::lm10m();
        let n = transformer_lm(&cfg);
        assert_eq!(n.total_params(), cfg.param_count());
    }

    #[test]
    fn cuts_only_after_blocks() {
        let n = transformer_lm(&TransformerCfg::lm1m());
        for i in n.legal_cuts() {
            let name = &n.layers[i].name;
            assert!(
                name == "embed" || name.ends_with("_mlp") || name == "ln_f" || name == "lm_head",
                "bad cut point {name}"
            );
        }
    }
}
