//! VGG-16 (configuration D, Simonyan & Zisserman 2014) — 13 conv + 3 FC
//! layers, ~138.4M parameters at 224×224 input.

use crate::model::costs::*;
use crate::model::{Layer, LayerKind, Network};

/// Build VGG-16 for a square input of side `img` (224 in the paper).
pub fn vgg16(img: u64) -> Network {
    assert!(img % 32 == 0, "vgg16 needs input divisible by 32");
    let mut layers = Vec::new();
    let mut h = img;
    let mut cin = 3u64;
    // (n_convs, channels) per block — configuration D.
    let blocks = [(2u64, 64u64), (2, 128), (3, 256), (3, 512), (3, 512)];
    for (bi, &(n, cout)) in blocks.iter().enumerate() {
        for ci in 0..n {
            let name = format!("conv{}_{}", bi + 1, ci + 1);
            let f = conv2d_flops(3, cin, cout, h, h);
            let act = cout * h * h;
            layers.push(Layer::new(name, LayerKind::Conv2d, f, conv2d_params(3, cin, cout), act));
            // ReLU folded into the conv cost model (negligible) — explicit
            // layer omitted to keep the cut-point set at conv granularity.
            cin = cout;
        }
        h /= 2;
        layers.push(Layer::new(
            format!("pool{}", bi + 1),
            LayerKind::Pool,
            act_flops(cin * h * h, 1.0),
            0,
            cin * h * h,
        ));
    }
    // Classifier: 512*7*7 → 4096 → 4096 → 1000.
    let flat = cin * h * h;
    for (i, (inp, out)) in [(flat, 4096u64), (4096, 4096), (4096, 1000)].iter().enumerate() {
        layers.push(Layer::new(
            format!("fc{}", i + 6),
            LayerKind::Linear,
            linear_flops(*inp, *out, 1),
            linear_params(*inp, *out),
            *out,
        ));
    }
    layers.push(Layer::new("softmax", LayerKind::Softmax, act_flops(1000, 5.0), 0, 1000));
    Network::new("vgg16", layers, 3 * img * img)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_matches_reference() {
        // Canonical VGG-16: 138,357,544 parameters.
        let n = vgg16(224);
        let p = n.total_params();
        assert!(
            (p as i64 - 138_357_544i64).abs() < 200_000,
            "vgg16 params {p} should be ≈138.36M"
        );
    }

    #[test]
    fn flops_matches_reference() {
        // Canonical VGG-16 fwd: ~15.5 GMACs = ~31 GFLOPs at 224².
        let n = vgg16(224);
        let g = n.total_flops_fwd() / 1e9;
        assert!(g > 29.0 && g < 33.0, "vgg16 fwd GFLOPs {g}");
    }

    #[test]
    fn layer_structure() {
        let n = vgg16(224);
        // 13 conv + 5 pool + 3 fc + softmax = 22
        assert_eq!(n.len(), 22);
        assert_eq!(n.layers[0].name, "conv1_1");
        // fc6 dominates params (102.8M)
        let fc6 = n.layers.iter().find(|l| l.name == "fc6").unwrap();
        assert_eq!(fc6.params, 25088 * 4096 + 4096);
    }

    #[test]
    fn activations_shrink_monotonically_across_pools() {
        let n = vgg16(224);
        let pools: Vec<u64> = n
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Pool)
            .map(|l| l.act_out_elems)
            .collect();
        for w in pools.windows(2) {
            assert!(w[1] <= w[0]);
        }
    }
}
