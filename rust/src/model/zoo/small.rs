//! Small networks for tests, examples and fast exploration demos.

use crate::model::costs::*;
use crate::model::{Layer, LayerKind, Network};

/// AlexNet (Krizhevsky 2012) — 5 conv + 3 FC, ~61M params.
pub fn alexnet() -> Network {
    let mut layers = Vec::new();
    // (name, k, cin, cout, hout, pool_after)
    let convs: [(&str, u64, u64, u64, u64, bool); 5] = [
        ("conv1", 11, 3, 96, 55, true),
        ("conv2", 5, 96, 256, 27, true),
        ("conv3", 3, 256, 384, 13, false),
        ("conv4", 3, 384, 384, 13, false),
        ("conv5", 3, 384, 256, 13, true),
    ];
    let mut h;
    for (name, k, cin, cout, hout, pool) in convs {
        layers.push(Layer::new(
            name,
            LayerKind::Conv2d,
            conv2d_flops(k, cin, cout, hout, hout),
            conv2d_params(k, cin, cout),
            cout * hout * hout,
        ));
        h = hout;
        if pool {
            let hp = (h - 1) / 2;
            layers.push(Layer::new(
                format!("{name}_pool"),
                LayerKind::Pool,
                act_flops(cout * hp * hp, 1.0),
                0,
                cout * hp * hp,
            ));
        }
    }
    for (i, (inp, out)) in [(256u64 * 6 * 6, 4096u64), (4096, 4096), (4096, 1000)]
        .iter()
        .enumerate()
    {
        layers.push(Layer::new(
            format!("fc{}", i + 6),
            LayerKind::Linear,
            linear_flops(*inp, *out, 1),
            linear_params(*inp, *out),
            *out,
        ));
    }
    layers.push(Layer::new("softmax", LayerKind::Softmax, act_flops(1000, 5.0), 0, 1000));
    Network::new("alexnet", layers, 3 * 224 * 224)
}

/// A plain MLP over the given layer widths (`dims[0]` is the input width).
pub fn mlp(dims: &[u64]) -> Network {
    assert!(dims.len() >= 2, "mlp needs at least input+output dims");
    let layers = dims
        .windows(2)
        .enumerate()
        .map(|(i, w)| {
            Layer::new(
                format!("fc{i}"),
                LayerKind::Linear,
                linear_flops(w[0], w[1], 1),
                linear_params(w[0], w[1]),
                w[1],
            )
        })
        .collect();
    Network::new("mlp", layers, dims[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_params() {
        // Canonical AlexNet: ~61M (62.38M with local-response-norm variants).
        let p = alexnet().total_params() as f64;
        assert!(p > 55e6 && p < 65e6, "alexnet params {p}");
    }

    #[test]
    fn mlp_structure() {
        let n = mlp(&[784, 512, 256, 10]);
        assert_eq!(n.len(), 3);
        assert_eq!(n.total_params(), (784 * 512 + 512) + (512 * 256 + 256) + (256 * 10 + 10));
        assert_eq!(n.input_elems, 784);
    }

    #[test]
    #[should_panic(expected = "at least input+output")]
    fn mlp_too_short() {
        mlp(&[10]);
    }
}
