//! ResNet-50 (He et al. 2016) — bottleneck residual network, ~25.6M
//! parameters. Blocks are flattened into conv layers; layers inside a
//! bottleneck are marked `no_cut` so pipeline cuts never sever a skip edge.

use crate::model::costs::*;
use crate::model::{Layer, LayerKind, Network};

/// One bottleneck block: 1×1 reduce → 3×3 → 1×1 expand (+ optional
/// projection shortcut). `stride` applies to the 3×3.
fn bottleneck(
    layers: &mut Vec<Layer>,
    name: &str,
    cin: u64,
    cmid: u64,
    cout: u64,
    h_in: u64,
    stride: u64,
) -> u64 {
    let h_out = h_in / stride;
    // 1x1 reduce
    layers.push(
        Layer::new(
            format!("{name}_a"),
            LayerKind::Conv2d,
            conv2d_flops(1, cin, cmid, h_in, h_in),
            conv2d_params(1, cin, cmid),
            cmid * h_in * h_in,
        )
        .no_cut(),
    );
    // 3x3 (stride)
    layers.push(
        Layer::new(
            format!("{name}_b"),
            LayerKind::Conv2d,
            conv2d_flops(3, cmid, cmid, h_out, h_out),
            conv2d_params(3, cmid, cmid),
            cmid * h_out * h_out,
        )
        .no_cut(),
    );
    // 1x1 expand
    layers.push(
        Layer::new(
            format!("{name}_c"),
            LayerKind::Conv2d,
            conv2d_flops(1, cmid, cout, h_out, h_out),
            conv2d_params(1, cmid, cout),
            cout * h_out * h_out,
        )
        .no_cut(),
    );
    // projection shortcut when shape changes
    let proj_params =
        if cin != cout || stride != 1 { conv2d_params(1, cin, cout) } else { 0 };
    let proj_flops = if proj_params > 0 {
        conv2d_flops(1, cin, cout, h_out, h_out)
    } else {
        0.0
    };
    // residual add closes the block — cut allowed after it
    layers.push(Layer::new(
        format!("{name}_add"),
        LayerKind::Glue,
        proj_flops + act_flops(cout * h_out * h_out, 2.0),
        proj_params,
        cout * h_out * h_out,
    ));
    h_out
}

/// Build ResNet-50 for a square input of side `img` (224 in the paper).
pub fn resnet50(img: u64) -> Network {
    assert!(img % 32 == 0, "resnet50 needs input divisible by 32");
    let mut layers = Vec::new();
    let mut h = img / 2; // conv1 stride 2
    layers.push(Layer::new(
        "conv1",
        LayerKind::Conv2d,
        conv2d_flops(7, 3, 64, h, h),
        conv2d_params(7, 3, 64),
        64 * h * h,
    ));
    h /= 2; // maxpool stride 2
    layers.push(Layer::new("pool1", LayerKind::Pool, act_flops(64 * h * h, 1.0), 0, 64 * h * h));

    let stages: [(u64, u64, u64, usize); 4] =
        [(64, 64, 256, 3), (256, 128, 512, 4), (512, 256, 1024, 6), (1024, 512, 2048, 3)];
    let mut cin;
    let mut cur_in = 64u64;
    for (si, &(_, cmid, cout, nblocks)) in stages.iter().enumerate() {
        for b in 0..nblocks {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            let name = format!("res{}_{}", si + 2, b + 1);
            h = bottleneck(&mut layers, &name, cur_in, cmid, cout, h, stride);
            cur_in = cout;
        }
        cin = cout;
        let _ = cin;
    }
    // global average pool + fc
    layers.push(Layer::new("avgpool", LayerKind::Pool, act_flops(2048 * h * h, 1.0), 0, 2048));
    layers.push(Layer::new(
        "fc",
        LayerKind::Linear,
        linear_flops(2048, 1000, 1),
        linear_params(2048, 1000),
        1000,
    ));
    layers.push(Layer::new("softmax", LayerKind::Softmax, act_flops(1000, 5.0), 0, 1000));
    Network::new("resnet50", layers, 3 * img * img)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_matches_reference() {
        // Canonical ResNet-50: 25.557M params (ours omits batchnorms'
        // 53k affine params folded into convs' bias terms — within 1%).
        let n = resnet50(224);
        let p = n.total_params() as f64;
        assert!((p - 25.55e6).abs() / 25.55e6 < 0.02, "resnet50 params {p}");
    }

    #[test]
    fn flops_matches_reference() {
        // Canonical: ~4.1 GMACs = ~8.2 GFLOPs fwd.
        let n = resnet50(224);
        let g = n.total_flops_fwd() / 1e9;
        assert!(g > 7.5 && g < 9.0, "resnet50 fwd GFLOPs {g}");
    }

    #[test]
    fn cuts_only_at_block_boundaries() {
        let n = resnet50(224);
        for i in n.legal_cuts() {
            let l = &n.layers[i];
            assert!(
                !l.name.ends_with("_a") && !l.name.ends_with("_b") && !l.name.ends_with("_c"),
                "illegal cut point inside block: {}",
                l.name
            );
        }
        // 16 blocks → at least 16 block-boundary cuts + stem
        assert!(n.legal_cuts().len() >= 17);
    }

    #[test]
    fn block_count() {
        let n = resnet50(224);
        let adds = n.layers.iter().filter(|l| l.name.ends_with("_add")).count();
        assert_eq!(adds, 16); // 3+4+6+3
    }
}
