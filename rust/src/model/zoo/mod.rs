//! Model zoo: builders for every workload the paper evaluates (VGG-16,
//! ResNet-50, GNMT-8 / GNMT-L) plus the Transformer-LM used by the real
//! training engine and small nets for tests and examples.

mod gnmt;
mod resnet;
mod small;
mod transformer;
mod vgg;

pub use gnmt::{gnmt, gnmt_l};
pub use resnet::resnet50;
pub use small::{alexnet, mlp};
pub use transformer::{transformer_lm, TransformerCfg};
pub use vgg::vgg16;

/// Look a zoo model up by name (CLI / config convenience).
///
/// Supported: `vgg16`, `resnet50`, `alexnet`, `gnmt8`, `gnmt16`,
/// `gnmt-l<L>` (e.g. `gnmt-l32`), `lm10m`, `lm100m`.
pub fn by_name(name: &str) -> Option<crate::model::Network> {
    match name {
        "vgg16" => Some(vgg16(224)),
        "resnet50" => Some(resnet50(224)),
        "alexnet" => Some(alexnet()),
        "gnmt8" => Some(gnmt(8, 1024, 32000, 50)),
        "gnmt16" => Some(gnmt(16, 1024, 32000, 50)),
        "lm10m" => Some(transformer_lm(&TransformerCfg::lm10m())),
        "lm100m" => Some(transformer_lm(&TransformerCfg::lm100m())),
        _ => {
            if let Some(l) = name.strip_prefix("gnmt-l") {
                l.parse::<u64>().ok().map(gnmt_l)
            } else {
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves() {
        for n in ["vgg16", "resnet50", "alexnet", "gnmt8", "gnmt-l32", "lm10m", "lm100m"] {
            assert!(by_name(n).is_some(), "{n} should resolve");
        }
        assert!(by_name("nope").is_none());
    }
}
