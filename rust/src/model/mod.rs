//! DNN model intermediate representation: a linear sequence of [`Layer`]s
//! with per-sample compute/parameter/activation costs ([`graph::Network`]),
//! cost formulas ([`costs`]) and a zoo of the paper's workloads
//! ([`zoo`]: VGG-16, ResNet-50, GNMT-8/GNMT-L, Transformer-LM, AlexNet, MLP).
//!
//! BaPipe partitions a network *vertically* into contiguous stages, so the
//! IR is a layer list; residual blocks (ResNet, Transformer) are flattened
//! but only layers with `cut_ok == true` are legal stage boundaries.

pub mod costs;
pub mod graph;
pub mod layer;
pub mod zoo;

pub use graph::Network;
pub use layer::{Layer, LayerKind};
