//! [`Network`]: a named linear sequence of layers plus aggregate queries
//! (total FLOPs/params, legal cut points, prefix sums for the partitioner).

use super::layer::Layer;

/// A DNN expressed as a linear layer sequence (pipeline-partitionable IR).
#[derive(Debug, Clone)]
pub struct Network {
    /// Model name (`vgg16`, `gnmt8`, ...).
    pub name: String,
    /// The layers in execution order.
    pub layers: Vec<Layer>,
    /// Input activation elements per sample (e.g. `3*224*224`).
    pub input_elems: u64,
}

impl Network {
    /// Construct; panics on an empty layer list.
    pub fn new(name: impl Into<String>, layers: Vec<Layer>, input_elems: u64) -> Network {
        assert!(!layers.is_empty(), "Network must have at least one layer");
        Network { name: name.into(), layers, input_elems }
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// Always false (constructor enforces non-empty) — for clippy symmetry.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Total trainable parameters.
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params).sum()
    }

    /// Total fwd FLOPs per sample.
    pub fn total_flops_fwd(&self) -> f64 {
        self.layers.iter().map(|l| l.flops_fwd).sum()
    }

    /// Total bwd FLOPs per sample.
    pub fn total_flops_bwd(&self) -> f64 {
        self.layers.iter().map(|l| l.flops_bwd).sum()
    }

    /// Output activation elements of layer `i` (the tensor crossing a cut
    /// placed after layer `i`). For `i == len-1` this is the model output.
    pub fn act_out(&self, i: usize) -> u64 {
        self.layers[i].act_out_elems
    }

    /// Input activation elements of layer `i` (output of `i-1`, or the
    /// network input for `i == 0`).
    pub fn act_in(&self, i: usize) -> u64 {
        if i == 0 {
            self.input_elems
        } else {
            self.layers[i - 1].act_out_elems
        }
    }

    /// Indices after which a pipeline cut is legal (excludes the last
    /// layer — a cut there would produce an empty stage).
    pub fn legal_cuts(&self) -> Vec<usize> {
        (0..self.layers.len() - 1).filter(|&i| self.layers[i].cut_ok).collect()
    }

    /// Prefix sums of (fwd+bwd) FLOPs — `prefix[i]` = sum of layers `0..i`.
    /// Length `len+1`; used by the DP partitioner for O(1) range queries.
    pub fn flops_prefix(&self) -> Vec<f64> {
        let mut p = Vec::with_capacity(self.layers.len() + 1);
        p.push(0.0);
        let mut acc = 0.0;
        for l in &self.layers {
            acc += l.flops_total();
            p.push(acc);
        }
        p
    }

    /// Prefix sums of parameter counts (length `len+1`).
    pub fn params_prefix(&self) -> Vec<u64> {
        let mut p = Vec::with_capacity(self.layers.len() + 1);
        p.push(0);
        let mut acc = 0u64;
        for l in &self.layers {
            acc += l.params;
            p.push(acc);
        }
        p
    }

    /// One-line description.
    pub fn describe(&self) -> String {
        format!(
            "{}: {} layers, {} params, {:.2} GFLOPs fwd/sample",
            self.name,
            self.len(),
            crate::util::fmt_params(self.total_params()),
            self.total_flops_fwd() / 1e9,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Layer, LayerKind};

    fn tiny() -> Network {
        Network::new(
            "tiny",
            vec![
                Layer::new("a", LayerKind::Linear, 10.0, 5, 4),
                Layer::new("b", LayerKind::Act, 1.0, 0, 4).no_cut(),
                Layer::new("c", LayerKind::Linear, 20.0, 8, 2),
            ],
            3,
        )
    }

    #[test]
    fn totals() {
        let n = tiny();
        assert_eq!(n.total_params(), 13);
        assert_eq!(n.total_flops_fwd(), 31.0);
        assert_eq!(n.total_flops_bwd(), 62.0);
    }

    #[test]
    fn act_in_out() {
        let n = tiny();
        assert_eq!(n.act_in(0), 3);
        assert_eq!(n.act_out(0), 4);
        assert_eq!(n.act_in(2), 4);
        assert_eq!(n.act_out(2), 2);
    }

    #[test]
    fn legal_cuts_respect_no_cut() {
        let n = tiny();
        assert_eq!(n.legal_cuts(), vec![0]); // after "a"; "b" is no_cut; "c" is last
    }

    #[test]
    fn prefix_sums() {
        let n = tiny();
        let p = n.flops_prefix();
        assert_eq!(p.len(), 4);
        assert_eq!(p[0], 0.0);
        assert_eq!(p[3], 31.0 + 62.0);
        let q = n.params_prefix();
        assert_eq!(q, vec![0, 5, 5, 13]);
    }

    #[test]
    #[should_panic(expected = "at least one layer")]
    fn empty_panics() {
        Network::new("x", vec![], 1);
    }
}
