//! A single layer: per-sample FLOP counts, parameter count and output
//! activation size — the quantities the profiler, partitioner and memory
//! model consume.

/// Coarse layer taxonomy. Used by the FPGA profiler (DSP mapping differs
/// for conv vs. gemm vs. elementwise) and the coarse-grained partitioner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution.
    Conv2d,
    /// Fully-connected / dense / projection.
    Linear,
    /// LSTM layer (per-token gates).
    Lstm,
    /// Embedding lookup.
    Embedding,
    /// Multi-head self-attention (fused block component).
    Attention,
    /// Normalization (batchnorm / layernorm).
    Norm,
    /// Pooling.
    Pool,
    /// Elementwise activation (ReLU/GELU/...).
    Act,
    /// Softmax / classifier head / loss.
    Softmax,
    /// Residual add or concat glue.
    Glue,
}

impl LayerKind {
    /// Is this a "compute" layer for DSP-utilization purposes (vs glue)?
    pub fn is_compute(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv2d
                | LayerKind::Linear
                | LayerKind::Lstm
                | LayerKind::Attention
                | LayerKind::Embedding
        )
    }
}

/// One layer of a [`super::Network`]. All quantities are **per sample**
/// (batch size 1); schedulers and memory models scale by micro-batch size.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Human-readable name (`conv1_1`, `enc_lstm3`, ...).
    pub name: String,
    /// Taxonomy tag.
    pub kind: LayerKind,
    /// Forward FLOPs per sample.
    pub flops_fwd: f64,
    /// Backward FLOPs per sample (typically ≈ 2× forward for conv/gemm).
    pub flops_bwd: f64,
    /// Trainable parameter count.
    pub params: u64,
    /// Output activation **elements** per sample (bytes = × dtype width).
    pub act_out_elems: u64,
    /// May the pipeline be cut **after** this layer? (false inside
    /// residual blocks whose skip edge would cross the cut).
    pub cut_ok: bool,
}

impl Layer {
    /// Construct with backward defaulting to 2× forward FLOPs and
    /// `cut_ok = true`.
    pub fn new(
        name: impl Into<String>,
        kind: LayerKind,
        flops_fwd: f64,
        params: u64,
        act_out_elems: u64,
    ) -> Layer {
        Layer {
            name: name.into(),
            kind,
            flops_fwd,
            flops_bwd: 2.0 * flops_fwd,
            params,
            act_out_elems,
            cut_ok: true,
        }
    }

    /// Builder: set backward FLOPs explicitly.
    pub fn with_bwd(mut self, flops_bwd: f64) -> Layer {
        self.flops_bwd = flops_bwd;
        self
    }

    /// Builder: forbid cutting after this layer.
    pub fn no_cut(mut self) -> Layer {
        self.cut_ok = false;
        self
    }

    /// Total (fwd + bwd) FLOPs per sample.
    pub fn flops_total(&self) -> f64 {
        self.flops_fwd + self.flops_bwd
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let l = Layer::new("fc", LayerKind::Linear, 100.0, 10, 5);
        assert_eq!(l.flops_bwd, 200.0);
        assert!(l.cut_ok);
        assert_eq!(l.flops_total(), 300.0);
    }

    #[test]
    fn builders() {
        let l = Layer::new("res", LayerKind::Conv2d, 10.0, 1, 1)
            .with_bwd(15.0)
            .no_cut();
        assert_eq!(l.flops_bwd, 15.0);
        assert!(!l.cut_ok);
    }

    #[test]
    fn kind_compute() {
        assert!(LayerKind::Conv2d.is_compute());
        assert!(LayerKind::Lstm.is_compute());
        assert!(!LayerKind::Pool.is_compute());
        assert!(!LayerKind::Glue.is_compute());
    }
}
