//! Per-layer cost formulas: FLOPs, parameter counts and activation sizes
//! for the building blocks used by the zoo. Forward multiply-accumulate is
//! counted as 2 FLOPs; backward for gemm/conv is 2× forward (grad wrt
//! inputs + grad wrt weights).

/// Conv2d forward FLOPs per sample: `2 · K² · Cin · Cout · Hout · Wout`.
pub fn conv2d_flops(k: u64, cin: u64, cout: u64, hout: u64, wout: u64) -> f64 {
    2.0 * (k * k * cin * cout * hout * wout) as f64
}

/// Conv2d parameters: `K² · Cin · Cout + Cout` (bias).
pub fn conv2d_params(k: u64, cin: u64, cout: u64) -> u64 {
    k * k * cin * cout + cout
}

/// Linear forward FLOPs per sample (optionally per `tokens` positions).
pub fn linear_flops(inp: u64, out: u64, tokens: u64) -> f64 {
    2.0 * (inp * out * tokens) as f64
}

/// Linear parameters: `in·out + out`.
pub fn linear_params(inp: u64, out: u64) -> u64 {
    inp * out + out
}

/// LSTM layer parameters: 4 gates of `(input + hidden + 1) · hidden`.
pub fn lstm_params(input: u64, hidden: u64) -> u64 {
    4 * (input + hidden + 1) * hidden
}

/// LSTM forward FLOPs for a sequence of `seq` tokens.
pub fn lstm_flops(input: u64, hidden: u64, seq: u64) -> f64 {
    // 4 gate gemms per token + elementwise gate math (~32h, negligible but counted)
    (2.0 * (4 * (input + hidden) * hidden) as f64 + 32.0 * hidden as f64) * seq as f64
}

/// Multi-head self-attention fwd FLOPs for `seq` tokens, model dim `d`:
/// QKV projections + scores + context + output projection.
pub fn attention_flops(d: u64, seq: u64) -> f64 {
    let proj = 2.0 * (4 * d * d * seq) as f64; // Q,K,V,O projections
    let scores = 2.0 * (seq * seq * d) as f64; // QK^T
    let ctx = 2.0 * (seq * seq * d) as f64; // scores·V
    proj + scores + ctx
}

/// Attention parameters (Q,K,V,O projections with bias).
pub fn attention_params(d: u64) -> u64 {
    4 * (d * d + d)
}

/// Transformer MLP (d → 4d → d, GELU) fwd FLOPs for `seq` tokens.
pub fn mlp_flops(d: u64, seq: u64) -> f64 {
    2.0 * (2 * d * 4 * d * seq) as f64
}

/// Transformer MLP parameters.
pub fn mlp_params(d: u64) -> u64 {
    (d * 4 * d + 4 * d) + (4 * d * d + d)
}

/// LayerNorm parameters (scale + shift).
pub fn norm_params(d: u64) -> u64 {
    2 * d
}

/// Batch/Layer-norm fwd FLOPs (≈8 per element).
pub fn norm_flops(elems: u64) -> f64 {
    8.0 * elems as f64
}

/// Elementwise activation FLOPs (1 per element; GELU ≈ 8).
pub fn act_flops(elems: u64, per_elem: f64) -> f64 {
    per_elem * elems as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_flops_match_hand_calc() {
        // 3x3 conv, 64->64, 224x224 out: 2*9*64*64*224*224
        let f = conv2d_flops(3, 64, 64, 224, 224);
        assert_eq!(f, 2.0 * 9.0 * 64.0 * 64.0 * 224.0 * 224.0);
    }

    #[test]
    fn linear_params_match() {
        assert_eq!(linear_params(4096, 1000), 4096 * 1000 + 1000);
    }

    #[test]
    fn lstm_params_reference() {
        // PyTorch LSTM(1024,1024) has 4*(1024+1024+2)*1024 weights+biases(2 bias vecs);
        // we fold to one bias: 4*(2049)*1024.
        assert_eq!(lstm_params(1024, 1024), 4 * 2049 * 1024);
    }

    #[test]
    fn attention_scales_quadratically_in_seq() {
        let a = attention_flops(512, 128);
        let b = attention_flops(512, 256);
        // projection part doubles, score part quadruples → ratio in (2,4)
        let r = b / a;
        assert!(r > 2.0 && r < 4.0, "ratio {r}");
    }

    #[test]
    fn mlp_params_match() {
        let d = 64;
        assert_eq!(mlp_params(d), (d * 4 * d + 4 * d) + (4 * d * d + d));
    }
}
