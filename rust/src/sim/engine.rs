//! The discrete-event pipeline simulator.
//!
//! Executes the static per-stage op sequences of `schedule::generators`
//! with data dependencies and communication delays:
//!
//! * **Sync (GPU)** producer: the transfer *starts when the op ends* —
//!   `arrival = end + xfer` (Fig. 4b).
//! * **Async (FPGA)** producer: the transfer *streams during the op* —
//!   `arrival = max(end, start + xfer)` (Fig. 4a); if the link is slower
//!   than the op, the difference is exactly the paper's "demand
//!   bandwidth" shortfall.
//!
//! The 1F1B-SNO vs 1F1B-SO contrast of Table 2 *emerges* from these rules
//! plus the warm-up depths — there is no schedule-specific timing code —
//! and the analytical-vs-DES cross-check tests hold both sides honest.

use crate::cluster::ExecMode;
use crate::schedule::{generators, Op, ScheduleKind, StageProgram};

/// Cost-model inputs to a simulation.
#[derive(Debug, Clone)]
pub struct SimSpec {
    /// Schedule to run.
    pub kind: ScheduleKind,
    /// Micro-batches per mini-batch.
    pub m: usize,
    /// Per-stage forward time per micro-batch (s).
    pub fwd: Vec<f64>,
    /// Per-stage backward time per micro-batch (s).
    pub bwd: Vec<f64>,
    /// Per-stage optimizer-update time (s).
    pub update: Vec<f64>,
    /// Per-edge forward-activation transfer time (s), `len = n-1`.
    pub fwd_xfer: Vec<f64>,
    /// Per-edge backward-error transfer time (s), `len = n-1`.
    pub bwd_xfer: Vec<f64>,
    /// Per-stage execution mode.
    pub exec: Vec<ExecMode>,
}

impl SimSpec {
    /// Uniform spec (the Tables-1/2 setting: balanced stages, equal hops).
    pub fn uniform(
        kind: ScheduleKind,
        n: usize,
        m: usize,
        f: f64,
        b: f64,
        sr: f64,
        exec: ExecMode,
    ) -> SimSpec {
        SimSpec {
            kind,
            m,
            fwd: vec![f; n],
            bwd: vec![b; n],
            update: vec![0.0; n],
            fwd_xfer: vec![sr; n.saturating_sub(1)],
            bwd_xfer: vec![sr; n.saturating_sub(1)],
            exec: vec![exec; n],
        }
    }

    /// Number of stages.
    pub fn n(&self) -> usize {
        self.fwd.len()
    }
}

/// One executed op, for timelines and debugging.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Executed {
    /// Stage index.
    pub stage: usize,
    /// The op.
    pub op: Op,
    /// Start time (s).
    pub start: f64,
    /// End time (s).
    pub end: f64,
}

/// Simulation outputs.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Mini-batch makespan (s).
    pub makespan: f64,
    /// Mean idle fraction across stages (the pipeline-bubble rate).
    pub bubble_fraction: f64,
    /// Peak in-flight (fwd-done, bwd-not-done) micro-batches per stage.
    pub peak_in_flight: Vec<usize>,
    /// Full event trace (ordered by stage, then time).
    pub events: Vec<Executed>,
}

/// Simulate one mini-batch of `spec.kind` on the given cost model.
pub fn simulate(spec: &SimSpec) -> SimResult {
    let n = spec.n();
    assert!(n >= 1);
    assert_eq!(spec.bwd.len(), n);
    assert_eq!(spec.fwd_xfer.len(), n - 1);
    assert_eq!(spec.bwd_xfer.len(), n - 1);
    let m = spec.m;
    let programs: Vec<StageProgram> =
        (0..n).map(|i| generators::program(spec.kind, n, i, m)).collect();

    // arrival[i][k]: when stage i's forward input for micro-batch k is ready
    let mut f_arrival = vec![vec![f64::NAN; m]; n];
    // stage 0's inputs are local
    for k in 0..m {
        f_arrival[0][k] = 0.0;
    }
    let mut b_arrival = vec![vec![f64::NAN; m]; n];
    for k in 0..m {
        // last stage starts backward from its own loss
        b_arrival[n - 1][k] = 0.0;
    }
    let mut f_done = vec![vec![false; m]; n];

    let mut cursor = vec![0.0f64; n];
    let mut pc = vec![0usize; n];
    let mut busy = vec![0.0f64; n];
    // Transfers serialize per edge *direction* (a channel carries one
    // message at a time — this is what makes activation-heavy nets
    // communication-bound, the paper's ResNet-50 observation). Links are
    // full duplex: PCIe DMA and FPGA transceivers have independent lanes
    // per direction.
    let mut f_chan_free = vec![0.0f64; n.saturating_sub(1)];
    let mut b_chan_free = vec![0.0f64; n.saturating_sub(1)];
    let mut events: Vec<Executed> = Vec::new();
    let mut in_flight = vec![0usize; n];
    let mut peak_in_flight = vec![0usize; n];

    // FBP slots cost F+B regardless of occupancy (statically partitioned
    // DSP engines — Section 3.2.1 / Table 1).
    let op_duration = |i: usize, op: &Op| -> f64 {
        match spec.kind {
            ScheduleKind::FbpAs => match op {
                Op::Update => spec.update[i],
                _ => spec.fwd[i] + spec.bwd[i],
            },
            _ => match op {
                Op::Fwd { .. } => spec.fwd[i],
                Op::Bwd { .. } => spec.bwd[i],
                Op::FwdBwd { .. } => spec.fwd[i] + spec.bwd[i],
                Op::Update => spec.update[i],
            },
        }
    };

    let total_ops: usize = programs.iter().map(|p| p.ops.len()).sum();
    let mut executed = 0usize;
    while executed < total_ops {
        let mut progressed = false;
        for i in 0..n {
            while pc[i] < programs[i].ops.len() {
                let op = programs[i].ops[pc[i]];
                // dependency check → earliest data-ready time
                let ready: Option<f64> = match op {
                    Op::Fwd { mb } => {
                        let a = f_arrival[i][mb];
                        if a.is_nan() {
                            None
                        } else {
                            Some(a)
                        }
                    }
                    Op::Bwd { mb } => {
                        if !f_done[i][mb] {
                            None
                        } else {
                            let a = b_arrival[i][mb];
                            if a.is_nan() {
                                None
                            } else {
                                Some(a)
                            }
                        }
                    }
                    Op::FwdBwd { fwd_mb, bwd_mb } => {
                        let fa = f_arrival[i][fwd_mb];
                        let ba = b_arrival[i][bwd_mb];
                        let f_ok = f_done[i][bwd_mb] || fwd_mb == bwd_mb;
                        if fa.is_nan() || ba.is_nan() || !f_ok {
                            None
                        } else {
                            Some(fa.max(ba))
                        }
                    }
                    Op::Update => Some(cursor[i]),
                };
                let Some(data_ready) = ready else { break };
                let start = cursor[i].max(data_ready);
                let dur = op_duration(i, &op);
                let end = start + dur;
                cursor[i] = end;
                busy[i] += dur;
                events.push(Executed { stage: i, op, start, end });
                // produce outputs (transfers serialize on the edge channel)
                let fwd_mb_done = match op {
                    Op::Fwd { mb } => Some(mb),
                    Op::FwdBwd { fwd_mb, .. } => Some(fwd_mb),
                    _ => None,
                };
                if let Some(mb) = fwd_mb_done {
                    f_done[i][mb] = true;
                    in_flight[i] += 1;
                    peak_in_flight[i] = peak_in_flight[i].max(in_flight[i]);
                    if i + 1 < n {
                        let x = spec.fwd_xfer[i];
                        let free = f_chan_free[i];
                        let arr = match spec.exec[i] {
                            ExecMode::Sync => end.max(free) + x,
                            // streamed during the op when the channel allows
                            ExecMode::Async => end.max(start.max(free) + x),
                        };
                        f_chan_free[i] = arr;
                        f_arrival[i + 1][mb] = arr;
                    }
                }
                let bwd_mb_done = match op {
                    Op::Bwd { mb } => Some(mb),
                    Op::FwdBwd { bwd_mb, .. } => Some(bwd_mb),
                    _ => None,
                };
                if let Some(mb) = bwd_mb_done {
                    in_flight[i] = in_flight[i].saturating_sub(1);
                    if i > 0 {
                        let x = spec.bwd_xfer[i - 1];
                        let free = b_chan_free[i - 1];
                        let arr = match spec.exec[i] {
                            ExecMode::Sync => end.max(free) + x,
                            ExecMode::Async => end.max(start.max(free) + x),
                        };
                        b_chan_free[i - 1] = arr;
                        b_arrival[i - 1][mb] = arr;
                    }
                }
                pc[i] += 1;
                executed += 1;
                progressed = true;
            }
        }
        assert!(
            progressed,
            "schedule deadlock: {:?} n={n} m={m} (pc={pc:?})",
            spec.kind
        );
    }

    let makespan = cursor.iter().cloned().fold(0.0, f64::max);
    let bubble = if makespan > 0.0 {
        (0..n).map(|i| 1.0 - busy[i] / makespan).sum::<f64>() / n as f64
    } else {
        0.0
    };
    events.sort_by(|a, b| (a.stage, a.start).partial_cmp(&(b.stage, b.start)).unwrap());
    SimResult { makespan, bubble_fraction: bubble, peak_in_flight, events }
}

/// Epoch time: `n_minibatches` mini-batches. Intra-batch schedules fully
/// drain between mini-batches (weight update barrier), so the epoch is a
/// clean multiple; PipeDream pipelines *across* mini-batches — its fill
/// cost is paid once and the steady period is the bottleneck-stage time.
pub fn epoch_time(spec: &SimSpec, n_minibatches: usize) -> f64 {
    epoch_from_makespan(simulate(spec).makespan, spec, n_minibatches)
}

/// [`epoch_time`] when the one-mini-batch makespan is already known —
/// lets the planner reuse a single DES run for both the mini-batch and
/// the epoch figure instead of simulating twice.
pub fn epoch_from_makespan(one: f64, spec: &SimSpec, n_minibatches: usize) -> f64 {
    match spec.kind {
        ScheduleKind::PipeDream => {
            let n = spec.n();
            // steady period per mini-batch (= per "micro-batch" in
            // PipeDream's inter-batch pipeline): bottleneck stage F+B,
            // plus its non-overlapped communication (Section 4.2.1).
            let period = (0..n)
                .map(|i| {
                    let comm = if i + 1 < n {
                        spec.fwd_xfer[i] + spec.bwd_xfer[i]
                    } else {
                        0.0
                    };
                    spec.fwd[i] + spec.bwd[i] + comm
                })
                .fold(0.0, f64::max);
            one + period * spec.m as f64 * (n_minibatches.saturating_sub(1)) as f64
        }
        _ => one * n_minibatches as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::analytical::{self, Symbols};

    fn syms(m: usize, n: usize, f: f64, b: f64, sr: f64) -> Symbols {
        Symbols { m, n, f, b, sr, a: 0.0, w: 0.0 }
    }

    #[test]
    fn des_matches_table1_async_no_comm_cost() {
        // 1F1B-AS with overlapped comm: exactly (M+N-1)(F+B).
        for (m, n) in [(8usize, 3usize), (16, 4), (4, 2), (32, 8)] {
            let spec =
                SimSpec::uniform(ScheduleKind::OneFOneBAs, n, m, 1.0, 2.0, 0.1, ExecMode::Async);
            let r = simulate(&spec);
            let t = analytical::minibatch_time(ScheduleKind::OneFOneBAs, &syms(m, n, 1.0, 2.0, 0.1));
            let rel = (r.makespan - t).abs() / t;
            assert!(rel < 0.08, "1F1B-AS m={m} n={n}: DES {} vs closed {t}", r.makespan);
        }
    }

    #[test]
    fn des_fbp_matches_static_dsp_partition_depth() {
        // FBP-AS under FPDeep's *static* DSP partition: every slot costs
        // F+B, and the fwd stream needs a 2(N-1)+1-slot round trip before
        // backwards begin, so the exact makespan is (M+2N-1)(F+B). The
        // paper's Table 1 reports the idealized (M+N-1)(F+B) — the two
        // agree asymptotically in M (the regime the paper operates in:
        // "we set M large enough to ignore the pipeline bubble").
        for (m, n) in [(8usize, 3usize), (16, 4), (64, 4)] {
            let spec = SimSpec::uniform(ScheduleKind::FbpAs, n, m, 1.0, 2.0, 0.1, ExecMode::Async);
            let r = simulate(&spec);
            let exact = (m + 2 * n - 1) as f64 * 3.0;
            assert!((r.makespan - exact).abs() < 1e-9, "m={m} n={n}: {} vs {exact}", r.makespan);
            // asymptotic agreement with Table 1
            let t1 = analytical::minibatch_time(ScheduleKind::FbpAs, &syms(m, n, 1.0, 2.0, 0.1));
            if m >= 64 {
                assert!((r.makespan - t1).abs() / t1 < 0.10);
            }
        }
    }

    #[test]
    fn des_matches_table2_so() {
        // 1F1B-SO: (M+N-1)(F+B) + (N-1)·2SR.
        for (m, n, sr) in [(8usize, 3usize, 0.25), (16, 4, 0.1), (12, 3, 0.5)] {
            let spec = SimSpec::uniform(ScheduleKind::OneFOneBSo, n, m, 1.0, 1.0, sr, ExecMode::Sync);
            let r = simulate(&spec);
            let t = analytical::minibatch_time(ScheduleKind::OneFOneBSo, &syms(m, n, 1.0, 1.0, sr));
            let rel = (r.makespan - t).abs() / t;
            assert!(rel < 0.10, "m={m} n={n} sr={sr}: DES {} vs closed {t}", r.makespan);
        }
    }

    #[test]
    fn des_sno_pays_comm_proportional_to_m() {
        // The SNO-vs-SO gap must grow with M (Table 2's key qualitative claim).
        let gap = |m: usize| {
            let sno = simulate(&SimSpec::uniform(
                ScheduleKind::OneFOneBSno, 3, m, 1.0, 1.0, 0.4, ExecMode::Sync,
            ))
            .makespan;
            let so = simulate(&SimSpec::uniform(
                ScheduleKind::OneFOneBSo, 3, m, 1.0, 1.0, 0.4, ExecMode::Sync,
            ))
            .makespan;
            sno - so
        };
        let g8 = gap(8);
        let g32 = gap(32);
        assert!(g32 > 1.5 * g8, "gap(32)={g32} should outgrow gap(8)={g8}");
    }

    #[test]
    fn des_zero_comm_sno_equals_so() {
        let sno = simulate(&SimSpec::uniform(
            ScheduleKind::OneFOneBSno, 4, 16, 1.0, 2.0, 0.0, ExecMode::Sync,
        ));
        let so = simulate(&SimSpec::uniform(
            ScheduleKind::OneFOneBSo, 4, 16, 1.0, 2.0, 0.0, ExecMode::Sync,
        ));
        assert!((sno.makespan - so.makespan).abs() < 1e-9);
        assert!((sno.makespan - (16.0 + 3.0) * 3.0).abs() < 1e-9);
    }

    #[test]
    fn gpipe_peak_in_flight_is_m() {
        let spec = SimSpec::uniform(ScheduleKind::GPipe, 3, 8, 1.0, 2.0, 0.1, ExecMode::Sync);
        let r = simulate(&spec);
        assert_eq!(r.peak_in_flight, vec![8, 8, 8]);
    }

    #[test]
    fn one_f_one_b_peak_in_flight_matches_stash_depth() {
        let n = 4;
        let m = 16;
        let spec =
            SimSpec::uniform(ScheduleKind::OneFOneBAs, n, m, 1.0, 1.0, 0.0, ExecMode::Async);
        let r = simulate(&spec);
        for i in 0..n {
            assert_eq!(
                r.peak_in_flight[i],
                ScheduleKind::OneFOneBAs.stash_depth(n, i, m),
                "stage {i}"
            );
        }
    }

    #[test]
    fn so_peak_in_flight_doubles() {
        let n = 3;
        let m = 16;
        let r = simulate(&SimSpec::uniform(
            ScheduleKind::OneFOneBSo, n, m, 1.0, 1.0, 0.2, ExecMode::Sync,
        ));
        for i in 0..n {
            assert_eq!(r.peak_in_flight[i], (2 * (n - i)).min(m), "stage {i}");
        }
    }

    #[test]
    fn bubble_shrinks_with_m() {
        let b = |m| {
            simulate(&SimSpec::uniform(
                ScheduleKind::OneFOneBAs, 4, m, 1.0, 1.0, 0.0, ExecMode::Async,
            ))
            .bubble_fraction
        };
        assert!(b(64) < b(8));
        assert!(b(64) < 0.1);
    }

    #[test]
    fn single_stage_no_bubble() {
        let spec = SimSpec::uniform(ScheduleKind::OneFOneBSno, 1, 4, 1.0, 2.0, 0.0, ExecMode::Sync);
        let r = simulate(&spec);
        assert!((r.makespan - 12.0).abs() < 1e-12);
        assert!(r.bubble_fraction.abs() < 1e-12);
    }

    #[test]
    fn pipedream_epoch_amortizes_fill() {
        let spec =
            SimSpec::uniform(ScheduleKind::PipeDream, 4, 1, 1.0, 1.0, 0.1, ExecMode::Sync);
        let e10 = epoch_time(&spec, 10);
        let e1 = epoch_time(&spec, 1);
        // marginal cost per extra mini-batch ≈ F+B+2SR = 2.2
        let marginal = (e10 - e1) / 9.0;
        assert!((marginal - 2.2).abs() < 0.05, "marginal {marginal}");
    }

    #[test]
    fn intra_batch_epoch_is_multiple() {
        let spec =
            SimSpec::uniform(ScheduleKind::OneFOneBSo, 3, 8, 1.0, 1.0, 0.1, ExecMode::Sync);
        let one = simulate(&spec).makespan;
        assert!((epoch_time(&spec, 7) - 7.0 * one).abs() < 1e-9);
    }

    #[test]
    fn events_are_non_overlapping_per_stage() {
        let spec = SimSpec::uniform(ScheduleKind::FbpAs, 3, 8, 1.0, 2.0, 0.3, ExecMode::Async);
        let r = simulate(&spec);
        for i in 0..3 {
            let evs: Vec<_> = r.events.iter().filter(|e| e.stage == i).collect();
            for w in evs.windows(2) {
                assert!(w[1].start >= w[0].end - 1e-12, "overlap at stage {i}");
            }
        }
    }
}
