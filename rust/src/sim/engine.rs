//! The discrete-event pipeline simulator.
//!
//! Executes the static per-stage op sequences of `schedule::generators`
//! with data dependencies and communication delays:
//!
//! * **Sync (GPU)** producer: the transfer *starts when the op ends* —
//!   `arrival = end + xfer` (Fig. 4b).
//! * **Async (FPGA)** producer: the transfer *streams during the op* —
//!   `arrival = max(end, start + xfer)` (Fig. 4a); if the link is slower
//!   than the op, the difference is exactly the paper's "demand
//!   bandwidth" shortfall.
//!
//! The 1F1B-SNO vs 1F1B-SO contrast of Table 2 *emerges* from these rules
//! plus the warm-up depths — there is no schedule-specific timing code —
//! and the analytical-vs-DES cross-check tests hold both sides honest.
//!
//! Two execution paths share one ready-list core over flat
//! structure-of-arrays state ([`SimArena`]):
//!
//! * [`simulate_fast`] — trace-free, allocation-free across calls with a
//!   reused arena; the planner's hot path.
//! * [`simulate_full`] (= [`simulate`]) — additionally materializes the
//!   event trace for timelines, figures and tests, pre-sized to the
//!   exact op count.
//!
//! The seed round-robin polling implementation is retained as
//! [`simulate_reference`]: an independent oracle the SoA core must match
//! bit-exactly (property-tested below) and the baseline
//! `benches/planner_scale.rs` measures the fast path against.

use crate::cluster::ExecMode;
use crate::schedule::{generators, Op, ScheduleKind, StageProgram};

/// Cost-model inputs to a simulation.
#[derive(Debug, Clone)]
pub struct SimSpec {
    /// Schedule to run.
    pub kind: ScheduleKind,
    /// Micro-batches per mini-batch.
    pub m: usize,
    /// Per-stage forward time per micro-batch (s).
    pub fwd: Vec<f64>,
    /// Per-stage backward time per micro-batch (s).
    pub bwd: Vec<f64>,
    /// Per-stage optimizer-update time (s).
    pub update: Vec<f64>,
    /// Per-edge forward-activation transfer time (s), `len = n-1`.
    pub fwd_xfer: Vec<f64>,
    /// Per-edge backward-error transfer time (s), `len = n-1`.
    pub bwd_xfer: Vec<f64>,
    /// Per-stage execution mode.
    pub exec: Vec<ExecMode>,
}

impl SimSpec {
    /// Uniform spec (the Tables-1/2 setting: balanced stages, equal hops).
    pub fn uniform(
        kind: ScheduleKind,
        n: usize,
        m: usize,
        f: f64,
        b: f64,
        sr: f64,
        exec: ExecMode,
    ) -> SimSpec {
        SimSpec {
            kind,
            m,
            fwd: vec![f; n],
            bwd: vec![b; n],
            update: vec![0.0; n],
            fwd_xfer: vec![sr; n.saturating_sub(1)],
            bwd_xfer: vec![sr; n.saturating_sub(1)],
            exec: vec![exec; n],
        }
    }

    /// Number of stages.
    pub fn n(&self) -> usize {
        self.fwd.len()
    }
}

/// One executed op, for timelines and debugging.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Executed {
    /// Stage index.
    pub stage: usize,
    /// The op.
    pub op: Op,
    /// Start time (s).
    pub start: f64,
    /// End time (s).
    pub end: f64,
}

/// Simulation outputs.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Mini-batch makespan (s).
    pub makespan: f64,
    /// Mean idle fraction across stages (the pipeline-bubble rate).
    pub bubble_fraction: f64,
    /// Peak in-flight (fwd-done, bwd-not-done) micro-batches per stage.
    pub peak_in_flight: Vec<usize>,
    /// Full event trace (ordered by stage, then time).
    pub events: Vec<Executed>,
}

/// Trace-free aggregate outputs of [`simulate_fast`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FastResult {
    /// Mini-batch makespan (s).
    pub makespan: f64,
    /// Mean idle fraction across stages (the pipeline-bubble rate).
    pub bubble_fraction: f64,
}

/// Reusable scratch state for the SoA simulator core: the per-stage op
/// table flattened into one buffer, and every `n × m` dependency array
/// flattened row-major (`stage * m + mb`). One arena per evaluator
/// worker thread makes the planner's inner DES loop allocation-free —
/// buffers keep their capacity across [`simulate_fast`] calls.
#[derive(Debug, Default)]
pub struct SimArena {
    /// All stage programs, concatenated (`ops_bounds` delimits stages).
    /// The batched core (`sim::batch`) leaves this empty — it reads the
    /// program through `generators::ProgramShape` instead.
    ops: Vec<Op>,
    /// `n + 1` offsets into `ops`; stage `i` owns `ops_bounds[i]..ops_bounds[i+1]`.
    ops_bounds: Vec<usize>,
    /// When stage `i`'s forward input for micro-batch `k` is ready (NaN = not yet).
    pub(crate) f_arrival: Vec<f64>,
    /// When stage `i`'s backward input for micro-batch `k` is ready (NaN = not yet).
    pub(crate) b_arrival: Vec<f64>,
    /// Has stage `i` completed the forward of micro-batch `k`?
    f_done: Vec<bool>,
    pub(crate) cursor: Vec<f64>,
    pub(crate) busy: Vec<f64>,
    pub(crate) pc: Vec<usize>,
    pub(crate) f_chan_free: Vec<f64>,
    pub(crate) b_chan_free: Vec<f64>,
    pub(crate) in_flight: Vec<usize>,
    pub(crate) peak_in_flight: Vec<usize>,
    /// Work list of stages whose next op may have become ready.
    pub(crate) ready: Vec<usize>,
    /// Is the stage already on the work list?
    pub(crate) queued: Vec<bool>,
}

impl SimArena {
    /// Empty arena; buffers grow to fit the first simulated spec and are
    /// reused afterwards.
    pub fn new() -> SimArena {
        SimArena::default()
    }

    /// Per-stage peak in-flight micro-batches of the **last** simulation
    /// run through this arena (the fast path's counterpart of
    /// [`SimResult::peak_in_flight`], exposed by borrow to stay
    /// allocation-free).
    pub fn peak_in_flight(&self) -> &[usize] {
        &self.peak_in_flight
    }

    /// Per-boundary `(forward, backward)` channel-clear times of the
    /// **last** simulation run through this arena (`len = n - 1` each):
    /// the instant boundary `i`'s activation/error traffic stops
    /// occupying its channel. The migration scheduler
    /// ([`crate::planner::migrate`]) reads these to place state-transfer
    /// slots into the draining pipeline's bubbles *behind* the last
    /// activation message on each link, instead of re-deriving link
    /// occupancy from an event trace.
    pub fn link_free_times(&self) -> (&[f64], &[f64]) {
        (&self.f_chan_free, &self.b_chan_free)
    }

    /// Release capacity beyond what an `(n, m)`-stage simulation needs.
    ///
    /// Arena buffers only ever grow, so one 1024-stage order-search probe
    /// would otherwise pin its peak allocation for the rest of the
    /// planner run even if every later family is tiny. All scratch state
    /// is cleared (the next `reset` rebuilds it); capacity shrinks to the
    /// `(n, m)` working set.
    pub fn shrink_to(&mut self, n: usize, m: usize) {
        let cells = n * m;
        // upper bound on ops per stage across all kinds: 2m + 1 (1F1B /
        // GPipe) and m + min(m, o) + 1 <= 2m + 1 (FBP)
        let ops_cap = n * (2 * m + 1);
        self.ops.clear();
        self.ops.shrink_to(ops_cap);
        self.ops_bounds.clear();
        self.ops_bounds.shrink_to(n + 1);
        self.f_arrival.clear();
        self.f_arrival.shrink_to(cells);
        self.b_arrival.clear();
        self.b_arrival.shrink_to(cells);
        self.f_done.clear();
        self.f_done.shrink_to(cells);
        self.cursor.clear();
        self.cursor.shrink_to(n);
        self.busy.clear();
        self.busy.shrink_to(n);
        self.pc.clear();
        self.pc.shrink_to(n);
        self.f_chan_free.clear();
        self.f_chan_free.shrink_to(n.saturating_sub(1));
        self.b_chan_free.clear();
        self.b_chan_free.shrink_to(n.saturating_sub(1));
        self.in_flight.clear();
        self.in_flight.shrink_to(n);
        self.peak_in_flight.clear();
        self.peak_in_flight.shrink_to(n);
        self.ready.clear();
        self.ready.shrink_to(n);
        self.queued.clear();
        self.queued.shrink_to(n);
    }

    /// Retained capacity of the `n × m` arrival matrices, in cells — the
    /// dominant term of the arena's footprint and the hysteresis input
    /// for [`SimArena::shrink_to`] policies.
    pub fn cells_capacity(&self) -> usize {
        self.f_arrival.capacity().max(self.b_arrival.capacity())
    }

    /// Total bytes currently retained across all buffers (capacities, not
    /// lengths) — what the capacity-release regression test asserts on.
    pub fn footprint_bytes(&self) -> usize {
        use std::mem::size_of;
        self.ops.capacity() * size_of::<Op>()
            + self.ops_bounds.capacity() * size_of::<usize>()
            + (self.f_arrival.capacity() + self.b_arrival.capacity()) * size_of::<f64>()
            + self.f_done.capacity()
            + (self.cursor.capacity()
                + self.busy.capacity()
                + self.f_chan_free.capacity()
                + self.b_chan_free.capacity())
                * size_of::<f64>()
            + (self.pc.capacity()
                + self.in_flight.capacity()
                + self.peak_in_flight.capacity()
                + self.ready.capacity())
                * size_of::<usize>()
            + self.queued.capacity()
    }

    /// Size and initialize every buffer for `spec`, keeping capacity.
    fn reset(&mut self, spec: &SimSpec) {
        let n = spec.n();
        let m = spec.m;
        self.ops.clear();
        self.ops_bounds.clear();
        self.ops_bounds.push(0);
        for i in 0..n {
            generators::program_into(spec.kind, n, i, m, &mut self.ops);
            self.ops_bounds.push(self.ops.len());
        }
        self.f_arrival.clear();
        self.f_arrival.resize(n * m, f64::NAN);
        self.b_arrival.clear();
        self.b_arrival.resize(n * m, f64::NAN);
        self.f_done.clear();
        self.f_done.resize(n * m, false);
        // Stage 0's forward inputs are local; the last stage starts
        // backward from its own loss.
        for k in 0..m {
            self.f_arrival[k] = 0.0;
            self.b_arrival[(n - 1) * m + k] = 0.0;
        }
        self.cursor.clear();
        self.cursor.resize(n, 0.0);
        self.busy.clear();
        self.busy.resize(n, 0.0);
        self.pc.clear();
        self.pc.resize(n, 0);
        self.f_chan_free.clear();
        self.f_chan_free.resize(n.saturating_sub(1), 0.0);
        self.b_chan_free.clear();
        self.b_chan_free.resize(n.saturating_sub(1), 0.0);
        self.in_flight.clear();
        self.in_flight.resize(n, 0);
        self.peak_in_flight.clear();
        self.peak_in_flight.resize(n, 0);
        self.ready.clear();
        self.ready.extend(0..n);
        self.queued.clear();
        self.queued.resize(n, true);
    }
}

/// Where executed ops go: a no-op for the fast path, a pre-sized
/// `Vec<Executed>` for the full path. Monomorphized, so the fast path
/// compiles to no trace code at all.
trait Sink {
    /// Called once, after the op table is built, with the exact op count.
    fn pre_size(&mut self, total_ops: usize);
    /// Record one executed op.
    fn record(&mut self, stage: usize, op: Op, start: f64, end: f64);
}

/// The trace-free sink.
struct NoTrace;

impl Sink for NoTrace {
    #[inline]
    fn pre_size(&mut self, _total_ops: usize) {}
    #[inline]
    fn record(&mut self, _stage: usize, _op: Op, _start: f64, _end: f64) {}
}

impl Sink for Vec<Executed> {
    fn pre_size(&mut self, total_ops: usize) {
        self.reserve(total_ops);
    }
    #[inline]
    fn record(&mut self, stage: usize, op: Op, start: f64, end: f64) {
        self.push(Executed { stage, op, start, end });
    }
}

/// Op duration under the spec's cost model. FBP slots cost F+B regardless
/// of occupancy (statically partitioned DSP engines — Section 3.2.1 /
/// Table 1).
fn op_duration(spec: &SimSpec, i: usize, op: &Op) -> f64 {
    match spec.kind {
        ScheduleKind::FbpAs => match op {
            Op::Update => spec.update[i],
            _ => spec.fwd[i] + spec.bwd[i],
        },
        _ => match op {
            Op::Fwd { .. } => spec.fwd[i],
            Op::Bwd { .. } => spec.bwd[i],
            Op::FwdBwd { .. } => spec.fwd[i] + spec.bwd[i],
            Op::Update => spec.update[i],
        },
    }
}

/// Shared ready-list core of [`simulate_fast`] / [`simulate_full`].
///
/// A stage leaves the work list when its next op is blocked on a
/// neighbour's data and re-enters only when that neighbour produces
/// something for it, so total scheduling work is `O(total ops)` — the
/// seed's round-robin polling re-scanned every stage per round
/// (worst-case quadratic at large `n·m`). Every timing expression is
/// copied verbatim from [`simulate_reference`]: op times are pure
/// dataflow (they depend only on arrivals and the stage's own cursor, in
/// program order), so the execution order difference cannot change any
/// computed value — the agreement is bit-exact, and property-tested.
///
/// Returns `(makespan, bubble_fraction)`; per-stage peaks stay in the
/// arena.
fn run_core<S: Sink>(spec: &SimSpec, arena: &mut SimArena, sink: &mut S) -> (f64, f64) {
    let n = spec.n();
    assert!(n >= 1);
    assert_eq!(spec.bwd.len(), n);
    assert_eq!(spec.update.len(), n);
    assert_eq!(spec.exec.len(), n);
    assert_eq!(spec.fwd_xfer.len(), n - 1);
    assert_eq!(spec.bwd_xfer.len(), n - 1);
    let m = spec.m;
    arena.reset(spec);
    let total_ops = *arena.ops_bounds.last().unwrap();
    sink.pre_size(total_ops);

    let mut executed = 0usize;
    while let Some(i) = arena.ready.pop() {
        arena.queued[i] = false;
        let lo = arena.ops_bounds[i];
        let stage_len = arena.ops_bounds[i + 1] - lo;
        let row = i * m;
        while arena.pc[i] < stage_len {
            let op = arena.ops[lo + arena.pc[i]];
            // dependency check → earliest data-ready time
            let ready_at: Option<f64> = match op {
                Op::Fwd { mb } => {
                    let a = arena.f_arrival[row + mb];
                    if a.is_nan() {
                        None
                    } else {
                        Some(a)
                    }
                }
                Op::Bwd { mb } => {
                    if !arena.f_done[row + mb] {
                        None
                    } else {
                        let a = arena.b_arrival[row + mb];
                        if a.is_nan() {
                            None
                        } else {
                            Some(a)
                        }
                    }
                }
                Op::FwdBwd { fwd_mb, bwd_mb } => {
                    let fa = arena.f_arrival[row + fwd_mb];
                    let ba = arena.b_arrival[row + bwd_mb];
                    let f_ok = arena.f_done[row + bwd_mb] || fwd_mb == bwd_mb;
                    if fa.is_nan() || ba.is_nan() || !f_ok {
                        None
                    } else {
                        Some(fa.max(ba))
                    }
                }
                Op::Update => Some(arena.cursor[i]),
            };
            let Some(data_ready) = ready_at else { break };
            let start = arena.cursor[i].max(data_ready);
            let dur = op_duration(spec, i, &op);
            let end = start + dur;
            arena.cursor[i] = end;
            arena.busy[i] += dur;
            sink.record(i, op, start, end);
            // produce outputs (transfers serialize on the edge channel)
            let fwd_mb_done = match op {
                Op::Fwd { mb } => Some(mb),
                Op::FwdBwd { fwd_mb, .. } => Some(fwd_mb),
                _ => None,
            };
            if let Some(mb) = fwd_mb_done {
                arena.f_done[row + mb] = true;
                arena.in_flight[i] += 1;
                arena.peak_in_flight[i] = arena.peak_in_flight[i].max(arena.in_flight[i]);
                if i + 1 < n {
                    let x = spec.fwd_xfer[i];
                    let free = arena.f_chan_free[i];
                    let arr = match spec.exec[i] {
                        ExecMode::Sync => end.max(free) + x,
                        // streamed during the op when the channel allows
                        ExecMode::Async => end.max(start.max(free) + x),
                    };
                    arena.f_chan_free[i] = arr;
                    arena.f_arrival[(i + 1) * m + mb] = arr;
                    if !arena.queued[i + 1] {
                        arena.queued[i + 1] = true;
                        arena.ready.push(i + 1);
                    }
                }
            }
            let bwd_mb_done = match op {
                Op::Bwd { mb } => Some(mb),
                Op::FwdBwd { bwd_mb, .. } => Some(bwd_mb),
                _ => None,
            };
            if let Some(mb) = bwd_mb_done {
                arena.in_flight[i] = arena.in_flight[i].saturating_sub(1);
                if i > 0 {
                    let x = spec.bwd_xfer[i - 1];
                    let free = arena.b_chan_free[i - 1];
                    let arr = match spec.exec[i] {
                        ExecMode::Sync => end.max(free) + x,
                        ExecMode::Async => end.max(start.max(free) + x),
                    };
                    arena.b_chan_free[i - 1] = arr;
                    arena.b_arrival[(i - 1) * m + mb] = arr;
                    if !arena.queued[i - 1] {
                        arena.queued[i - 1] = true;
                        arena.ready.push(i - 1);
                    }
                }
            }
            arena.pc[i] += 1;
            executed += 1;
        }
    }
    assert_eq!(
        executed, total_ops,
        "schedule deadlock: {:?} n={n} m={m} (pc={:?})",
        spec.kind, arena.pc
    );

    let makespan = arena.cursor.iter().cloned().fold(0.0, f64::max);
    let bubble = if makespan > 0.0 {
        (0..n).map(|i| 1.0 - arena.busy[i] / makespan).sum::<f64>() / n as f64
    } else {
        0.0
    };
    (makespan, bubble)
}

/// Simulate one mini-batch without materializing an event trace — the
/// planner's hot path. Bit-exact with [`simulate_full`] (and with the
/// seed [`simulate_reference`]) on makespan, bubble fraction and
/// per-stage peak in-flight; the peaks are readable from
/// [`SimArena::peak_in_flight`] after the call.
pub fn simulate_fast(spec: &SimSpec, arena: &mut SimArena) -> FastResult {
    let (makespan, bubble_fraction) = run_core(spec, arena, &mut NoTrace);
    FastResult { makespan, bubble_fraction }
}

/// Simulate one mini-batch with the full event trace (timelines, figures,
/// tests). The trace is pre-sized to the exact op count and returned
/// ordered by stage, then start time.
pub fn simulate_full(spec: &SimSpec) -> SimResult {
    let mut arena = SimArena::new();
    let mut events: Vec<Executed> = Vec::new();
    let (makespan, bubble_fraction) = run_core(spec, &mut arena, &mut events);
    events.sort_by(|a, b| (a.stage, a.start).partial_cmp(&(b.stage, b.start)).unwrap());
    SimResult {
        makespan,
        bubble_fraction,
        peak_in_flight: arena.peak_in_flight().to_vec(),
        events,
    }
}

/// Simulate one mini-batch of `spec.kind` on the given cost model (the
/// trace-producing [`simulate_full`] path; callers that only need the
/// aggregates should prefer [`simulate_fast`] with a reused [`SimArena`]).
pub fn simulate(spec: &SimSpec) -> SimResult {
    simulate_full(spec)
}

/// The seed implementation: round-robin polling over nested per-stage
/// vectors, always materializing the trace. Retained verbatim as an
/// independent oracle for the SoA ready-list core (the bit-exactness
/// property test below) and as the measured baseline in
/// `benches/planner_scale.rs` / `BENCH_planner.json`.
pub fn simulate_reference(spec: &SimSpec) -> SimResult {
    let n = spec.n();
    assert!(n >= 1);
    assert_eq!(spec.bwd.len(), n);
    assert_eq!(spec.fwd_xfer.len(), n - 1);
    assert_eq!(spec.bwd_xfer.len(), n - 1);
    let m = spec.m;
    let programs: Vec<StageProgram> =
        (0..n).map(|i| generators::program(spec.kind, n, i, m)).collect();

    // arrival[i][k]: when stage i's forward input for micro-batch k is ready
    let mut f_arrival = vec![vec![f64::NAN; m]; n];
    // stage 0's inputs are local
    for k in 0..m {
        f_arrival[0][k] = 0.0;
    }
    let mut b_arrival = vec![vec![f64::NAN; m]; n];
    for k in 0..m {
        // last stage starts backward from its own loss
        b_arrival[n - 1][k] = 0.0;
    }
    let mut f_done = vec![vec![false; m]; n];

    let mut cursor = vec![0.0f64; n];
    let mut pc = vec![0usize; n];
    let mut busy = vec![0.0f64; n];
    // Transfers serialize per edge *direction* (a channel carries one
    // message at a time — this is what makes activation-heavy nets
    // communication-bound, the paper's ResNet-50 observation). Links are
    // full duplex: PCIe DMA and FPGA transceivers have independent lanes
    // per direction.
    let mut f_chan_free = vec![0.0f64; n.saturating_sub(1)];
    let mut b_chan_free = vec![0.0f64; n.saturating_sub(1)];
    let mut events: Vec<Executed> = Vec::new();
    let mut in_flight = vec![0usize; n];
    let mut peak_in_flight = vec![0usize; n];

    let total_ops: usize = programs.iter().map(|p| p.ops.len()).sum();
    let mut executed = 0usize;
    while executed < total_ops {
        let mut progressed = false;
        for i in 0..n {
            while pc[i] < programs[i].ops.len() {
                let op = programs[i].ops[pc[i]];
                // dependency check → earliest data-ready time
                let ready: Option<f64> = match op {
                    Op::Fwd { mb } => {
                        let a = f_arrival[i][mb];
                        if a.is_nan() {
                            None
                        } else {
                            Some(a)
                        }
                    }
                    Op::Bwd { mb } => {
                        if !f_done[i][mb] {
                            None
                        } else {
                            let a = b_arrival[i][mb];
                            if a.is_nan() {
                                None
                            } else {
                                Some(a)
                            }
                        }
                    }
                    Op::FwdBwd { fwd_mb, bwd_mb } => {
                        let fa = f_arrival[i][fwd_mb];
                        let ba = b_arrival[i][bwd_mb];
                        let f_ok = f_done[i][bwd_mb] || fwd_mb == bwd_mb;
                        if fa.is_nan() || ba.is_nan() || !f_ok {
                            None
                        } else {
                            Some(fa.max(ba))
                        }
                    }
                    Op::Update => Some(cursor[i]),
                };
                let Some(data_ready) = ready else { break };
                let start = cursor[i].max(data_ready);
                let dur = op_duration(spec, i, &op);
                let end = start + dur;
                cursor[i] = end;
                busy[i] += dur;
                events.push(Executed { stage: i, op, start, end });
                // produce outputs (transfers serialize on the edge channel)
                let fwd_mb_done = match op {
                    Op::Fwd { mb } => Some(mb),
                    Op::FwdBwd { fwd_mb, .. } => Some(fwd_mb),
                    _ => None,
                };
                if let Some(mb) = fwd_mb_done {
                    f_done[i][mb] = true;
                    in_flight[i] += 1;
                    peak_in_flight[i] = peak_in_flight[i].max(in_flight[i]);
                    if i + 1 < n {
                        let x = spec.fwd_xfer[i];
                        let free = f_chan_free[i];
                        let arr = match spec.exec[i] {
                            ExecMode::Sync => end.max(free) + x,
                            // streamed during the op when the channel allows
                            ExecMode::Async => end.max(start.max(free) + x),
                        };
                        f_chan_free[i] = arr;
                        f_arrival[i + 1][mb] = arr;
                    }
                }
                let bwd_mb_done = match op {
                    Op::Bwd { mb } => Some(mb),
                    Op::FwdBwd { bwd_mb, .. } => Some(bwd_mb),
                    _ => None,
                };
                if let Some(mb) = bwd_mb_done {
                    in_flight[i] = in_flight[i].saturating_sub(1);
                    if i > 0 {
                        let x = spec.bwd_xfer[i - 1];
                        let free = b_chan_free[i - 1];
                        let arr = match spec.exec[i] {
                            ExecMode::Sync => end.max(free) + x,
                            ExecMode::Async => end.max(start.max(free) + x),
                        };
                        b_chan_free[i - 1] = arr;
                        b_arrival[i - 1][mb] = arr;
                    }
                }
                pc[i] += 1;
                executed += 1;
                progressed = true;
            }
        }
        assert!(
            progressed,
            "schedule deadlock: {:?} n={n} m={m} (pc={pc:?})",
            spec.kind
        );
    }

    let makespan = cursor.iter().cloned().fold(0.0, f64::max);
    let bubble = if makespan > 0.0 {
        (0..n).map(|i| 1.0 - busy[i] / makespan).sum::<f64>() / n as f64
    } else {
        0.0
    };
    events.sort_by(|a, b| (a.stage, a.start).partial_cmp(&(b.stage, b.start)).unwrap());
    SimResult { makespan, bubble_fraction: bubble, peak_in_flight, events }
}

/// Epoch time: `n_minibatches` mini-batches. Intra-batch schedules fully
/// drain between mini-batches (weight update barrier), so the epoch is a
/// clean multiple; PipeDream pipelines *across* mini-batches — its fill
/// cost is paid once and the steady period is the bottleneck-stage time.
pub fn epoch_time(spec: &SimSpec, n_minibatches: usize) -> f64 {
    epoch_from_makespan(simulate(spec).makespan, spec, n_minibatches)
}

/// [`epoch_time`] when the one-mini-batch makespan is already known —
/// lets the planner reuse a single DES run for both the mini-batch and
/// the epoch figure instead of simulating twice.
pub fn epoch_from_makespan(one: f64, spec: &SimSpec, n_minibatches: usize) -> f64 {
    match spec.kind {
        ScheduleKind::PipeDream => {
            let n = spec.n();
            // steady period per mini-batch (= per "micro-batch" in
            // PipeDream's inter-batch pipeline): bottleneck stage F+B,
            // plus its non-overlapped communication (Section 4.2.1).
            let period = (0..n)
                .map(|i| {
                    let comm = if i + 1 < n {
                        spec.fwd_xfer[i] + spec.bwd_xfer[i]
                    } else {
                        0.0
                    };
                    spec.fwd[i] + spec.bwd[i] + comm
                })
                .fold(0.0, f64::max);
            one + period * spec.m as f64 * (n_minibatches.saturating_sub(1)) as f64
        }
        _ => one * n_minibatches as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::analytical::{self, Symbols};

    fn syms(m: usize, n: usize, f: f64, b: f64, sr: f64) -> Symbols {
        Symbols { m, n, f, b, sr, a: 0.0, w: 0.0 }
    }

    #[test]
    fn des_matches_table1_async_no_comm_cost() {
        // 1F1B-AS with overlapped comm: exactly (M+N-1)(F+B).
        for (m, n) in [(8usize, 3usize), (16, 4), (4, 2), (32, 8)] {
            let spec =
                SimSpec::uniform(ScheduleKind::OneFOneBAs, n, m, 1.0, 2.0, 0.1, ExecMode::Async);
            let r = simulate(&spec);
            let t = analytical::minibatch_time(ScheduleKind::OneFOneBAs, &syms(m, n, 1.0, 2.0, 0.1));
            let rel = (r.makespan - t).abs() / t;
            assert!(rel < 0.08, "1F1B-AS m={m} n={n}: DES {} vs closed {t}", r.makespan);
        }
    }

    #[test]
    fn des_fbp_matches_static_dsp_partition_depth() {
        // FBP-AS under FPDeep's *static* DSP partition: every slot costs
        // F+B, and the fwd stream needs a 2(N-1)+1-slot round trip before
        // backwards begin, so the exact makespan is (M+2N-1)(F+B). The
        // paper's Table 1 reports the idealized (M+N-1)(F+B) — the two
        // agree asymptotically in M (the regime the paper operates in:
        // "we set M large enough to ignore the pipeline bubble").
        for (m, n) in [(8usize, 3usize), (16, 4), (64, 4)] {
            let spec = SimSpec::uniform(ScheduleKind::FbpAs, n, m, 1.0, 2.0, 0.1, ExecMode::Async);
            let r = simulate(&spec);
            let exact = (m + 2 * n - 1) as f64 * 3.0;
            assert!((r.makespan - exact).abs() < 1e-9, "m={m} n={n}: {} vs {exact}", r.makespan);
            // asymptotic agreement with Table 1
            let t1 = analytical::minibatch_time(ScheduleKind::FbpAs, &syms(m, n, 1.0, 2.0, 0.1));
            if m >= 64 {
                assert!((r.makespan - t1).abs() / t1 < 0.10);
            }
        }
    }

    #[test]
    fn des_matches_table2_so() {
        // 1F1B-SO: (M+N-1)(F+B) + (N-1)·2SR.
        for (m, n, sr) in [(8usize, 3usize, 0.25), (16, 4, 0.1), (12, 3, 0.5)] {
            let spec = SimSpec::uniform(ScheduleKind::OneFOneBSo, n, m, 1.0, 1.0, sr, ExecMode::Sync);
            let r = simulate(&spec);
            let t = analytical::minibatch_time(ScheduleKind::OneFOneBSo, &syms(m, n, 1.0, 1.0, sr));
            let rel = (r.makespan - t).abs() / t;
            assert!(rel < 0.10, "m={m} n={n} sr={sr}: DES {} vs closed {t}", r.makespan);
        }
    }

    #[test]
    fn des_sno_pays_comm_proportional_to_m() {
        // The SNO-vs-SO gap must grow with M (Table 2's key qualitative claim).
        let gap = |m: usize| {
            let sno = simulate(&SimSpec::uniform(
                ScheduleKind::OneFOneBSno, 3, m, 1.0, 1.0, 0.4, ExecMode::Sync,
            ))
            .makespan;
            let so = simulate(&SimSpec::uniform(
                ScheduleKind::OneFOneBSo, 3, m, 1.0, 1.0, 0.4, ExecMode::Sync,
            ))
            .makespan;
            sno - so
        };
        let g8 = gap(8);
        let g32 = gap(32);
        assert!(g32 > 1.5 * g8, "gap(32)={g32} should outgrow gap(8)={g8}");
    }

    #[test]
    fn des_zero_comm_sno_equals_so() {
        let sno = simulate(&SimSpec::uniform(
            ScheduleKind::OneFOneBSno, 4, 16, 1.0, 2.0, 0.0, ExecMode::Sync,
        ));
        let so = simulate(&SimSpec::uniform(
            ScheduleKind::OneFOneBSo, 4, 16, 1.0, 2.0, 0.0, ExecMode::Sync,
        ));
        assert!((sno.makespan - so.makespan).abs() < 1e-9);
        assert!((sno.makespan - (16.0 + 3.0) * 3.0).abs() < 1e-9);
    }

    #[test]
    fn gpipe_peak_in_flight_is_m() {
        let spec = SimSpec::uniform(ScheduleKind::GPipe, 3, 8, 1.0, 2.0, 0.1, ExecMode::Sync);
        let r = simulate(&spec);
        assert_eq!(r.peak_in_flight, vec![8, 8, 8]);
    }

    #[test]
    fn one_f_one_b_peak_in_flight_matches_stash_depth() {
        let n = 4;
        let m = 16;
        let spec =
            SimSpec::uniform(ScheduleKind::OneFOneBAs, n, m, 1.0, 1.0, 0.0, ExecMode::Async);
        let r = simulate(&spec);
        for i in 0..n {
            assert_eq!(
                r.peak_in_flight[i],
                ScheduleKind::OneFOneBAs.stash_depth(n, i, m),
                "stage {i}"
            );
        }
    }

    #[test]
    fn two_bw_peak_in_flight_matches_stash_depth() {
        // 2BW runs the 1F1B op sequence, so its simulated in-flight
        // high-water mark is exactly the analytical stash depth — the
        // anchor for the simulated-peak ≡ analytical-rows oracle.
        let n = 4;
        let m = 16;
        let spec = SimSpec::uniform(ScheduleKind::TwoBW, n, m, 1.0, 1.0, 0.1, ExecMode::Sync);
        let r = simulate(&spec);
        for i in 0..n {
            assert_eq!(
                r.peak_in_flight[i],
                ScheduleKind::TwoBW.stash_depth(n, i, m),
                "stage {i}"
            );
        }
    }

    #[test]
    fn so_peak_in_flight_doubles() {
        let n = 3;
        let m = 16;
        let r = simulate(&SimSpec::uniform(
            ScheduleKind::OneFOneBSo, n, m, 1.0, 1.0, 0.2, ExecMode::Sync,
        ));
        for i in 0..n {
            assert_eq!(r.peak_in_flight[i], (2 * (n - i)).min(m), "stage {i}");
        }
    }

    #[test]
    fn bubble_shrinks_with_m() {
        let b = |m| {
            simulate(&SimSpec::uniform(
                ScheduleKind::OneFOneBAs, 4, m, 1.0, 1.0, 0.0, ExecMode::Async,
            ))
            .bubble_fraction
        };
        assert!(b(64) < b(8));
        assert!(b(64) < 0.1);
    }

    #[test]
    fn single_stage_no_bubble() {
        let spec = SimSpec::uniform(ScheduleKind::OneFOneBSno, 1, 4, 1.0, 2.0, 0.0, ExecMode::Sync);
        let r = simulate(&spec);
        assert!((r.makespan - 12.0).abs() < 1e-12);
        assert!(r.bubble_fraction.abs() < 1e-12);
    }

    #[test]
    fn pipedream_epoch_amortizes_fill() {
        let spec =
            SimSpec::uniform(ScheduleKind::PipeDream, 4, 1, 1.0, 1.0, 0.1, ExecMode::Sync);
        let e10 = epoch_time(&spec, 10);
        let e1 = epoch_time(&spec, 1);
        // marginal cost per extra mini-batch ≈ F+B+2SR = 2.2
        let marginal = (e10 - e1) / 9.0;
        assert!((marginal - 2.2).abs() < 0.05, "marginal {marginal}");
    }

    #[test]
    fn intra_batch_epoch_is_multiple() {
        let spec =
            SimSpec::uniform(ScheduleKind::OneFOneBSo, 3, 8, 1.0, 1.0, 0.1, ExecMode::Sync);
        let one = simulate(&spec).makespan;
        assert!((epoch_time(&spec, 7) - 7.0 * one).abs() < 1e-9);
    }

    #[test]
    fn events_are_non_overlapping_per_stage() {
        let spec = SimSpec::uniform(ScheduleKind::FbpAs, 3, 8, 1.0, 2.0, 0.3, ExecMode::Async);
        let r = simulate(&spec);
        for i in 0..3 {
            let evs: Vec<_> = r.events.iter().filter(|e| e.stage == i).collect();
            for w in evs.windows(2) {
                assert!(w[1].start >= w[0].end - 1e-12, "overlap at stage {i}");
            }
        }
    }

    #[test]
    fn full_trace_is_sorted_by_stage_then_start_and_matches_reference() {
        // Regression for the documented events contract: the returned
        // trace is ordered by stage, then time — for every kind, and
        // identical to the seed implementation's trace.
        for (kind, exec) in [
            (ScheduleKind::OneFOneBAs, ExecMode::Async),
            (ScheduleKind::FbpAs, ExecMode::Async),
            (ScheduleKind::OneFOneBSno, ExecMode::Sync),
            (ScheduleKind::OneFOneBSo, ExecMode::Sync),
            (ScheduleKind::GPipe, ExecMode::Sync),
            (ScheduleKind::PipeDream, ExecMode::Sync),
            (ScheduleKind::TwoBW, ExecMode::Sync),
        ] {
            let spec = SimSpec::uniform(kind, 4, 6, 1.0, 2.0, 0.3, exec);
            let r = simulate_full(&spec);
            for w in r.events.windows(2) {
                assert!(
                    (w[0].stage, w[0].start) <= (w[1].stage, w[1].start),
                    "{kind:?}: events out of (stage, time) order: {:?} then {:?}",
                    w[0],
                    w[1]
                );
            }
            assert_eq!(r.events, simulate_reference(&spec).events, "{kind:?}");
        }
    }

    #[test]
    fn fast_full_and_reference_agree_bit_exactly_property() {
        // The SoA ready-list core — trace-free and trace-producing — must
        // agree with the seed polling oracle *bit-exactly* on makespan,
        // bubble_fraction and peak_in_flight, across every ScheduleKind
        // and mixed Sync/Async exec modes. The arena is reused across all
        // cases, so buffer re-initialization is exercised too.
        use crate::util::prop::{check, ensure, Config};
        use crate::util::rng::Rng;
        let kinds = ScheduleKind::all();
        let mut arena = SimArena::new();
        check(
            &Config { cases: 150, seed: 0x50_AFA57, max_size: 28 },
            |g| {
                let n = g.usize_in(1, 7);
                let m = g.usize_in(1, 28);
                let kind = kinds[g.usize_in(0, kinds.len())];
                let mut spec = SimSpec::uniform(kind, n, m, 1.0, 1.0, 0.0, ExecMode::Sync);
                let seed = g.usize_in(0, 1 << 30) as u64;
                let mut r = Rng::new(seed);
                for i in 0..n {
                    spec.fwd[i] = 0.01 + r.f64() * 2.0;
                    spec.bwd[i] = 0.01 + r.f64() * 3.0;
                    spec.update[i] = if r.f64() < 0.5 { 0.0 } else { r.f64() * 0.3 };
                    // per-stage mixed exec: the transfer rules are
                    // per-producer, independent of the schedule kind
                    spec.exec[i] =
                        if r.f64() < 0.5 { ExecMode::Sync } else { ExecMode::Async };
                }
                for i in 0..n.saturating_sub(1) {
                    spec.fwd_xfer[i] = r.f64() * 1.2;
                    spec.bwd_xfer[i] = r.f64() * 1.2;
                }
                spec
            },
            |spec| {
                let reference = simulate_reference(spec);
                let full = simulate_full(spec);
                let fast = simulate_fast(spec, &mut arena);
                ensure(
                    fast.makespan == reference.makespan,
                    format!("fast makespan {} != ref {}", fast.makespan, reference.makespan),
                )?;
                ensure(
                    fast.bubble_fraction == reference.bubble_fraction,
                    format!(
                        "fast bubble {} != ref {}",
                        fast.bubble_fraction, reference.bubble_fraction
                    ),
                )?;
                ensure(
                    arena.peak_in_flight() == &reference.peak_in_flight[..],
                    format!(
                        "fast peaks {:?} != ref {:?}",
                        arena.peak_in_flight(),
                        reference.peak_in_flight
                    ),
                )?;
                ensure(
                    full.makespan == reference.makespan
                        && full.bubble_fraction == reference.bubble_fraction
                        && full.peak_in_flight == reference.peak_in_flight,
                    "full aggregates differ from reference".to_string(),
                )?;
                ensure(full.events == reference.events, "traces differ".to_string())
            },
        );
    }

    #[test]
    fn arena_reuse_across_shapes_is_clean() {
        // big → small → big: state from a previous run must not leak.
        let mut arena = SimArena::new();
        let big =
            SimSpec::uniform(ScheduleKind::OneFOneBSo, 6, 32, 1.0, 2.0, 0.1, ExecMode::Sync);
        let small = SimSpec::uniform(ScheduleKind::GPipe, 2, 3, 1.0, 1.0, 0.2, ExecMode::Sync);
        let b1 = simulate_fast(&big, &mut arena);
        let s = simulate_fast(&small, &mut arena);
        let s_full = simulate_full(&small);
        assert_eq!(s.makespan, s_full.makespan);
        assert_eq!(arena.peak_in_flight(), &s_full.peak_in_flight[..]);
        let b2 = simulate_fast(&big, &mut arena);
        assert_eq!(b1, b2);
    }

    #[test]
    fn shrink_to_releases_capacity_and_keeps_results() {
        // Regression for capacity retention: a large spec grows the arena;
        // shrink_to must actually release the memory, and the arena must
        // still simulate correctly (both smaller and larger specs) after.
        let mut arena = SimArena::new();
        let big =
            SimSpec::uniform(ScheduleKind::OneFOneBSo, 16, 512, 1.0, 2.0, 0.1, ExecMode::Sync);
        let small = SimSpec::uniform(ScheduleKind::GPipe, 2, 4, 1.0, 1.0, 0.2, ExecMode::Sync);
        let big_ref = simulate_fast(&big, &mut arena);
        let grown = arena.footprint_bytes();
        arena.shrink_to(2, 4);
        let shrunk = arena.footprint_bytes();
        assert!(
            shrunk * 8 < grown,
            "shrink_to kept {shrunk} of {grown} bytes — capacity not released"
        );
        assert!(arena.cells_capacity() < 16 * 512);
        // still fully functional in both directions
        let s = simulate_fast(&small, &mut arena);
        assert_eq!(s, simulate_fast(&small, &mut SimArena::new()));
        let b2 = simulate_fast(&big, &mut arena);
        assert_eq!(b2, big_ref);
    }
}
