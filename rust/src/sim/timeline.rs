//! ASCII timeline renderer — regenerates the paper's Figs. 2, 4, 5 and 6
//! from simulator event traces.

use super::engine::{Executed, SimResult};
use crate::schedule::Op;

/// Render a simulation's event trace as one row per stage, one column per
/// `dt` seconds. Ops are labelled `F3`/`B3` (`*3` for FwdBwd slots of
/// micro-batch 3 fwd), idle time is `.`.
pub fn render(result: &SimResult, n_stages: usize, width: usize) -> String {
    assert!(width >= 10);
    // A zero-makespan result (degenerate spec, no events) has no time
    // axis to divide by — stub out all-idle rows rather than NaN columns.
    if !(result.makespan > 0.0) {
        let mut out = String::new();
        for s in 0..n_stages {
            out.push_str(&format!("acc{:<2}|{}|\n", s + 1, ".".repeat(width)));
        }
        return out;
    }
    let dt = result.makespan / width as f64;
    let mut out = String::new();
    for s in 0..n_stages {
        let evs: Vec<&Executed> = result.events.iter().filter(|e| e.stage == s).collect();
        let mut row = vec![b'.'; width];
        for e in evs {
            let lo = ((e.start / dt) as usize).min(width - 1);
            let hi = (((e.end / dt).ceil()) as usize).clamp(lo + 1, width);
            let label = op_label(&e.op);
            let bytes = label.as_bytes();
            for (j, cell) in row[lo..hi].iter_mut().enumerate() {
                *cell = if j < bytes.len() { bytes[j] } else { b'-' };
            }
        }
        out.push_str(&format!("acc{:<2}|{}|\n", s + 1, String::from_utf8_lossy(&row)));
    }
    out
}

fn op_label(op: &Op) -> String {
    match op {
        Op::Fwd { mb } => format!("F{}", mb + 1),
        Op::Bwd { mb } => format!("B{}", mb + 1),
        Op::FwdBwd { fwd_mb, .. } => format!("*{}", fwd_mb + 1),
        Op::Update => "U".to_string(),
    }
}

/// Render per-link occupancy with migration slots overlaid: one row per
/// physical link, `#` while the link still carries pipeline traffic
/// (`busy_until[link]`), `M` across each migration slot `(link, start,
/// end)`, `.` idle. The migration scheduler's visual counterpart of
/// [`render`] — a worked example lives in EXPERIMENTS.md's
/// "closing the elastic loop" section.
pub fn render_link_slots(
    n_links: usize,
    busy_until: &[f64],
    slots: &[(usize, f64, f64)],
    horizon: f64,
    width: usize,
) -> String {
    assert!(width >= 10);
    assert_eq!(busy_until.len(), n_links);
    let mut out = String::new();
    // Degenerate inputs still render *something*: callers print the
    // result unconditionally, so an empty string used to make e.g. a
    // single-device migration (zero links) vanish from the report.
    if n_links == 0 {
        return "links: (none)\n".to_string();
    }
    if !(horizon > 0.0) {
        for l in 0..n_links {
            out.push_str(&format!("link{:<2}|{}|\n", l, ".".repeat(width)));
        }
        return out;
    }
    let dt = horizon / width as f64;
    let col = |t: f64| ((t / dt) as usize).min(width);
    for l in 0..n_links {
        let mut row = vec![b'.'; width];
        for cell in row.iter_mut().take(col(busy_until[l])) {
            *cell = b'#';
        }
        for &(link, start, end) in slots.iter().filter(|s| s.0 == l) {
            debug_assert!(link == l);
            let lo = col(start).min(width - 1);
            let hi = ((end / dt).ceil() as usize).clamp(lo + 1, width);
            for cell in row[lo..hi].iter_mut() {
                *cell = b'M';
            }
        }
        out.push_str(&format!("link{:<2}|{}|\n", l, String::from_utf8_lossy(&row)));
    }
    out
}

/// A compact per-stage op-sequence line (no time axis) — useful when the
/// schedule's *order* is the point, e.g. Fig. 5's warm-up depths.
pub fn render_order(result: &SimResult, n_stages: usize) -> String {
    let mut out = String::new();
    for s in 0..n_stages {
        let seq: Vec<String> = result
            .events
            .iter()
            .filter(|e| e.stage == s)
            .map(|e| op_label(&e.op))
            .collect();
        out.push_str(&format!("acc{:<2}: {}\n", s + 1, seq.join(" ")));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ExecMode;
    use crate::schedule::ScheduleKind;
    use crate::sim::engine::{simulate, SimSpec};

    #[test]
    fn render_shape() {
        let spec =
            SimSpec::uniform(ScheduleKind::OneFOneBSno, 3, 4, 1.0, 2.0, 0.2, ExecMode::Sync);
        let r = simulate(&spec);
        let s = render(&r, 3, 80);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        for l in &lines {
            assert!(l.starts_with("acc"));
            assert_eq!(l.len(), 80 + 7, "{l}"); // "accN |" + cells + "|"
        }
        // later stages start later → leading idle dots
        assert!(lines[2].contains("|.."), "stage 3 has leading idle: {}", lines[2]);
    }

    #[test]
    fn render_order_warmup_depths() {
        let spec =
            SimSpec::uniform(ScheduleKind::OneFOneBAs, 3, 8, 1.0, 1.0, 0.0, ExecMode::Async);
        let r = simulate(&spec);
        let s = render_order(&r, 3);
        // Fig. 5(a): acc1 warms up F1 F2 F3; acc3 alternates immediately.
        assert!(s.lines().next().unwrap().starts_with("acc1 : F1 F2 F3 B1"));
        assert!(s.lines().nth(2).unwrap().starts_with("acc3 : F1 B1 F2 B2"));
    }

    #[test]
    fn link_slots_render_busy_then_migration() {
        // link 0 busy to t=5, migrating 6..8; link 1 idle then migrating 2..4
        let s =
            render_link_slots(2, &[5.0, 0.0], &[(0, 6.0, 8.0), (1, 2.0, 4.0)], 10.0, 20);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0], "link0 |##########..MMMM....|");
        assert_eq!(lines[1], "link1 |....MMMM............|");
    }

    #[test]
    fn degenerate_inputs_render_stub_lines() {
        // Zero links (single-device cluster): an explicit marker, not "".
        assert_eq!(render_link_slots(0, &[], &[], 10.0, 20), "links: (none)\n");
        // Zero horizon: one all-idle row per link, still pipe-framed.
        assert_eq!(render_link_slots(1, &[0.0], &[], 0.0, 20), "link0 |....................|\n");
        let two = render_link_slots(2, &[0.0, 0.0], &[], 0.0, 20);
        assert_eq!(two.lines().count(), 2);
        // Zero-makespan stage render: all-idle rows, no NaN columns.
        let empty = SimResult {
            makespan: 0.0,
            bubble_fraction: 0.0,
            peak_in_flight: vec![],
            events: vec![],
        };
        let s = render(&empty, 2, 20);
        assert_eq!(s, "acc1 |....................|\nacc2 |....................|\n");
    }

    #[test]
    fn fbp_slots_rendered_as_stars() {
        let spec = SimSpec::uniform(ScheduleKind::FbpAs, 2, 4, 1.0, 1.0, 0.0, ExecMode::Async);
        let r = simulate(&spec);
        let s = render_order(&r, 2);
        assert!(s.contains('*'), "{s}");
    }
}
