//! Data-parallel baseline model (Section 2.1: synchronized All-Reduce DP,
//! the paper's baseline for every experiment). Each device computes the
//! full network on its local batch, then ring-all-reduces gradients.

use crate::cluster::Cluster;
use crate::partition::memfit::{dp_memory_bytes, MemoryModel};
use crate::profile::Profile;

/// Result of the DP model for one mini-batch.
#[derive(Debug, Clone)]
pub struct DpResult {
    /// Mini-batch time (s).
    pub minibatch_time: f64,
    /// Compute portion (s).
    pub compute: f64,
    /// All-reduce portion (s).
    pub allreduce: f64,
    /// Per-device memory (bytes).
    pub memory: u64,
    /// Does it fit device memory?
    pub fits: bool,
}

/// Fraction of the (already GLOO-staged) link bandwidth a ring
/// all-reduce achieves on top of point-to-point — the CPU performs the
/// reduction between hops (the paper used GLOO because "NCCL does not
/// currently support multi-threads communication in safety").
pub const GLOO_EFFICIENCY: f64 = 0.7;

/// Ring all-reduce time for `bytes` of gradients over `n` devices with the
/// slowest link bandwidth `bw` (2(n-1)/n traversals of the full buffer).
pub fn ring_allreduce_time(bytes: f64, n: usize, bw: f64, latency: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let steps = 2 * (n - 1);
    steps as f64 * (bytes / n as f64 / (bw * GLOO_EFFICIENCY) + latency)
}

/// Model one DP mini-batch: local compute at per-device batch `b`, then a
/// non-overlapped gradient all-reduce (GLOO semantics — the paper's
/// communication backend; Section 4.2.1 notes NCCL was unusable).
pub fn minibatch(profile: &Profile, cluster: &Cluster, b: f64) -> DpResult {
    // `Cluster::new` guarantees N-1 links, but a hand-built struct can
    // carry an empty `links` vec: the min-bandwidth fold below would then
    // return +∞ and the all-reduce would silently collapse to pure
    // latency. Fail loudly instead.
    assert!(
        cluster.len() <= 1 || !cluster.links.is_empty(),
        "degenerate topology: {} devices but no links — the all-reduce time would collapse \
         to pure latency",
        cluster.len()
    );
    let l = profile.n_layers();
    // slowest device bounds the synchronized step
    let compute = (0..cluster.len())
        .map(|d| profile.fwd_time(d, 0, l, b) + profile.bwd_time(d, 0, l, b))
        .fold(0.0, f64::max);
    let grad_bytes = profile.param_bytes(0, l) as f64;
    let (bw, lat) = if cluster.len() > 1 {
        let bw = cluster.links.iter().map(|k| k.bandwidth).fold(f64::INFINITY, f64::min);
        let lat = cluster.links.iter().map(|k| k.latency).fold(0.0, f64::max);
        (bw, lat)
    } else {
        (f64::INFINITY, 0.0)
    };
    let allreduce = ring_allreduce_time(grad_bytes, cluster.len(), bw, lat);
    let mm = MemoryModel::data_parallel();
    let memory = dp_memory_bytes(profile, &mm, b);
    let fits = cluster
        .devices
        .iter()
        .all(|d| memory <= mm.usable(d.mem_capacity));
    DpResult { minibatch_time: compute + allreduce, compute, allreduce, memory, fits }
}

/// Epoch time from an already-computed [`minibatch`] result — callers
/// holding a `DpResult` (the planner computes one for the feasibility
/// check) convert it without re-summing the whole-network profile.
pub fn epoch_from(r: &DpResult, cluster: &Cluster, b: f64, samples: usize) -> f64 {
    // Same canonical global as the pipeline planner: a float-noise batch
    // must not hand DP one extra mini-batch in the epoch comparison.
    let global_batch = crate::util::canonical_global_batch(b, cluster.len());
    (samples as f64 / global_batch).ceil() * r.minibatch_time
}

/// Epoch time for `samples` training samples at per-device batch `b`.
pub fn epoch_time(profile: &Profile, cluster: &Cluster, b: f64, samples: usize) -> f64 {
    let r = minibatch(profile, cluster, b);
    epoch_from(&r, cluster, b, samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    #[test]
    fn ring_allreduce_scaling() {
        // 2(n-1)/n · bytes/(bw·gloo_eff)
        let t4 = ring_allreduce_time(1e9, 4, 1e9, 0.0);
        assert!((t4 - 6.0 * 0.25 / GLOO_EFFICIENCY).abs() < 1e-9);
        assert_eq!(ring_allreduce_time(1e9, 1, 1e9, 0.0), 0.0);
        // more devices → approaches 2·bytes/(bw·gloo_eff)
        let t16 = ring_allreduce_time(1e9, 16, 1e9, 0.0);
        assert!(t16 > t4 && t16 < 2.0 / GLOO_EFFICIENCY);
    }

    #[test]
    fn vgg_dp_is_comm_heavy_resnet_is_not() {
        // The paper's ResNet-50 result (pipeline degenerates to DP) stems
        // from ResNet's small weights (25.6M) vs VGG's huge ones (138M).
        let cl = presets::v100_cluster(4);
        let vgg = analytical::profile(&zoo::vgg16(224), &cl);
        let res = analytical::profile(&zoo::resnet50(224), &cl);
        let rv = minibatch(&vgg, &cl, 32.0);
        let rr = minibatch(&res, &cl, 32.0);
        let vgg_ratio = rv.allreduce / rv.compute;
        let res_ratio = rr.allreduce / rr.compute;
        assert!(vgg_ratio > 1.15 * res_ratio, "vgg {vgg_ratio} vs resnet {res_ratio}");
    }

    #[test]
    fn smaller_batch_worse_epoch_time() {
        // Table 3's DP column: B=32 is 0.55-0.62x of B=64.
        let cl = presets::v100_cluster(4);
        let p = analytical::profile(&zoo::vgg16(224), &cl);
        let e32 = epoch_time(&p, &cl, 32.0, 50_000);
        let e64 = epoch_time(&p, &cl, 64.0, 50_000);
        assert!(e32 > 1.2 * e64, "B=32 epoch {e32} vs B=64 {e64}");
    }

    #[test]
    fn giant_model_does_not_fit() {
        let cl = presets::v100_cluster(4);
        let p = analytical::profile(&zoo::gnmt_l(158), &cl);
        assert!(!minibatch(&p, &cl, 32.0).fits);
        let p2 = analytical::profile(&zoo::gnmt_l(32), &cl);
        assert!(minibatch(&p2, &cl, 32.0).fits);
    }

    #[test]
    #[should_panic(expected = "degenerate topology")]
    fn linkless_multi_device_cluster_rejected() {
        // Bypass `Cluster::new`'s link-count validation the way a careless
        // literal construction can.
        let cl = Cluster { devices: vec![presets::v100(), presets::v100()], links: vec![] };
        let p = analytical::profile(&zoo::resnet50(224), &presets::v100_cluster(2));
        minibatch(&p, &cl, 8.0);
    }

    #[test]
    fn single_device_no_allreduce() {
        let cl = presets::v100_cluster(1);
        let p = analytical::profile(&zoo::resnet50(224), &cl);
        let r = minibatch(&p, &cl, 8.0);
        assert_eq!(r.allreduce, 0.0);
        assert!(r.minibatch_time > 0.0);
    }
}
