//! Discrete-event simulation of pipeline schedules on accelerator
//! clusters: the [`engine`] executes the per-stage op sequences from
//! `schedule::generators` against a cost model, honouring synchronous
//! (GPU) vs asynchronous/streamed (FPGA) communication semantics;
//! [`timeline`] renders Figs. 4–6-style ASCII timelines; [`dp`] models the
//! data-parallel baseline with ring all-reduce.

pub mod dp;
pub mod engine;
pub mod timeline;

pub use engine::{simulate, simulate_fast, simulate_full, FastResult, SimArena, SimResult, SimSpec};
