//! Discrete-event simulation of pipeline schedules on accelerator
//! clusters: the [`engine`] executes the per-stage op sequences from
//! `schedule::generators` against a cost model, honouring synchronous
//! (GPU) vs asynchronous/streamed (FPGA) communication semantics;
//! [`batch`] layers batched-family and incremental passes on the same
//! arena; [`timeline`] renders Figs. 4–6-style ASCII timelines; [`dp`]
//! models the data-parallel baseline with ring all-reduce.
//!
//! Four simulate entry points, all bit-exact with each other; pick by
//! call pattern:
//!
//! * [`simulate_reference`](engine::simulate_reference) — the seed
//!   round-robin polling oracle. Slow (worst-case quadratic scheduling);
//!   use only as the correctness baseline in tests and benches.
//! * [`simulate_full`] (= [`simulate`]) — SoA core plus the full event
//!   trace, for timelines, figures and debugging one schedule.
//! * [`simulate_fast`] — trace-free SoA core over a reused [`SimArena`];
//!   the right call for *one-off* specs on a hot path.
//! * [`batch::FamilySim`] — table-free batched passes for *families* of
//!   related specs (M-grids: [`batch::FamilySim::run_grid`]) and
//!   incremental re-simulation of small per-row diffs against a
//!   checkpoint ([`batch::FamilySim::resimulate`], order-search probes);
//!   the planner's phase-B workhorse.

pub mod batch;
pub mod dp;
pub mod engine;
pub mod timeline;

pub use engine::{simulate, simulate_fast, simulate_full, FastResult, SimArena, SimResult, SimSpec};
