//! Batched-family and incremental discrete-event simulation.
//!
//! The planner's exploration axes (M-grids, adaptive-M bisection,
//! device-order probes) call the simulator with *families* of closely
//! related specs: same schedule kind and stage count, differing only in
//! micro-batch count or in a few stages' costs. [`FamilySim`] exploits
//! that structure two ways, both bit-exact with the engine:
//!
//! * **Batched cold passes** ([`FamilySim::run`] / [`FamilySim::run_grid`]):
//!   the stage program is read through the closed-form
//!   [`generators::ProgramShape`] view instead of the flat op table
//!   `SimArena::reset` rebuilds per candidate — at 1024 stages × M=4096
//!   that table is ~8M ops of build-and-stream traffic *per candidate*.
//!   The per-kind phase loops also drop the `f_done` gate (the generators
//!   guarantee a micro-batch's forward precedes its backward within a
//!   stage program — [`generators::validate`] — so the gate is
//!   structurally true whenever it is evaluated) and keep each stage's
//!   cursor/busy/channel state in registers across its program burst.
//! * **Incremental re-simulation** ([`FamilySim::resimulate`]): a
//!   checkpoint of the last full timeline plus a dirty-row mask derived
//!   from the spec diff. Only dirty rows replay; clean rows keep their
//!   checkpointed timings, with their input rows *bit-verified* against
//!   the checkpoint afterwards. Any mismatch grows the dirty set and
//!   replays again; past `2·dirty > n` the pass falls back to a cold run.
//!
//! Why the accepted incremental state is exact: op times are pure
//! dataflow (each op's time is a function of its input arrivals and the
//! stage's own cursor in program order), so the timing equations have a
//! unique solution. The accepted state satisfies every equation — dirty
//! rows are freshly computed from their inputs, and each clean row's
//! inputs are bit-identical to the checkpoint, under which its
//! checkpointed outputs were computed — so it *is* the full-run solution.
//! The property tests below pin all of this against `simulate_reference`.
//!
//! Every timing expression is copied verbatim from `engine::run_core`;
//! execution order cannot change any computed value (same pure-dataflow
//! argument the engine itself relies on), so agreement is bit-exact, not
//! approximate.

use crate::cluster::ExecMode;
use crate::schedule::generators::ProgramShape;
use crate::sim::engine::{FastResult, SimArena, SimSpec};

/// `begin_family` releases arena capacity when the retained `n × m`
/// working set exceeds this multiple of the incoming family's need.
const SHRINK_HYSTERESIS: usize = 4;

/// Counters exposing which path each [`FamilySim`] call took — the
/// incremental machinery's hit rate is workload-dependent, so tests and
/// diagnostics read it here instead of guessing.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct BatchStats {
    /// Cold batched passes (including the first pass after a shape change).
    pub full_runs: usize,
    /// Incremental replays accepted by the bit-exact fixpoint check.
    pub incremental_runs: usize,
    /// Replays abandoned for a cold pass because the dirty set grew past
    /// half the rows.
    pub fallback_runs: usize,
    /// Fixpoint rounds that had to grow the dirty set and replay again.
    pub fixpoint_rounds: usize,
}

/// Full post-run timeline state of one spec, for incremental replays.
#[derive(Debug, Clone)]
struct Checkpoint {
    spec: SimSpec,
    f_arrival: Vec<f64>,
    b_arrival: Vec<f64>,
    cursor: Vec<f64>,
    busy: Vec<f64>,
    f_chan_free: Vec<f64>,
    b_chan_free: Vec<f64>,
    peak_in_flight: Vec<usize>,
}

impl Checkpoint {
    fn capture(spec: &SimSpec, a: &SimArena) -> Checkpoint {
        Checkpoint {
            spec: spec.clone(),
            f_arrival: a.f_arrival.clone(),
            b_arrival: a.b_arrival.clone(),
            cursor: a.cursor.clone(),
            busy: a.busy.clone(),
            f_chan_free: a.f_chan_free.clone(),
            b_chan_free: a.b_chan_free.clone(),
            peak_in_flight: a.peak_in_flight.clone(),
        }
    }

    fn refresh(&mut self, spec: &SimSpec, a: &SimArena) {
        self.spec.clone_from(spec);
        self.f_arrival.clone_from(&a.f_arrival);
        self.b_arrival.clone_from(&a.b_arrival);
        self.cursor.clone_from(&a.cursor);
        self.busy.clone_from(&a.busy);
        self.f_chan_free.clone_from(&a.f_chan_free);
        self.b_chan_free.clone_from(&a.b_chan_free);
        self.peak_in_flight.clone_from(&a.peak_in_flight);
    }
}

/// A reusable batched/incremental simulator for one candidate family at a
/// time: owns a [`SimArena`], an optional replay [`Checkpoint`] and the
/// [`BatchStats`] counters. One per planner worker; `begin_family`
/// (called between families) drops the checkpoint and releases oversized
/// capacity via [`SimArena::shrink_to`].
#[derive(Debug, Default)]
pub struct FamilySim {
    arena: SimArena,
    ckpt: Option<Checkpoint>,
    dirty: Vec<bool>,
    /// Path counters for the lifetime of this value.
    pub stats: BatchStats,
}

impl FamilySim {
    /// Empty simulator; buffers grow to fit the first family and are
    /// reused afterwards.
    pub fn new() -> FamilySim {
        FamilySim::default()
    }

    /// Per-stage peak in-flight micro-batches of the last call, like
    /// [`SimArena::peak_in_flight`].
    pub fn peak_in_flight(&self) -> &[usize] {
        self.arena.peak_in_flight()
    }

    /// The owned arena (capacity inspection).
    pub fn arena(&self) -> &SimArena {
        &self.arena
    }

    /// Start a new candidate family of up to `n × m_max` timeline cells:
    /// drops the replay checkpoint (a different family's state can never
    /// seed a replay) and shrinks the arena when the retained capacity
    /// exceeds [`SHRINK_HYSTERESIS`]× the new working set — so one huge
    /// probe does not pin its peak allocation for the rest of a run.
    pub fn begin_family(&mut self, n: usize, m_max: usize) {
        self.ckpt = None;
        let need = (n * m_max).max(1);
        if self.arena.cells_capacity() > SHRINK_HYSTERESIS * need {
            self.arena.shrink_to(n, m_max.max(1));
        }
    }

    /// One cold batched pass: bit-exact with `simulate_fast` (and thus
    /// with `simulate_reference`) on makespan, bubble fraction and
    /// per-stage peaks, but table-free — the program is read through
    /// [`ProgramShape`]. Does not touch the replay checkpoint.
    pub fn run(&mut self, spec: &SimSpec) -> FastResult {
        self.stats.full_runs += 1;
        let (makespan, bubble_fraction) = run_cold(&mut self.arena, spec);
        FastResult { makespan, bubble_fraction }
    }

    /// Sweep a whole family (same kind and stage count, e.g. an M-grid)
    /// through one arena: sizes the arena once for the family's largest
    /// member, then runs each spec cold.
    pub fn run_grid(&mut self, family: &[SimSpec]) -> Vec<FastResult> {
        let Some(first) = family.first() else { return Vec::new() };
        let n = first.n();
        for s in family {
            assert_eq!(s.n(), n, "run_grid: mixed stage counts in one family");
            assert_eq!(s.kind, first.kind, "run_grid: mixed schedule kinds in one family");
        }
        let m_max = family.iter().map(|s| s.m).max().unwrap_or(1);
        self.begin_family(n, m_max);
        family.iter().map(|s| self.run(s)).collect()
    }

    /// Re-simulate `spec` against the previous `resimulate` call's
    /// checkpoint: rows whose parameters differ (compute costs, exec
    /// mode, or the transfer costs of the edges they produce into) are
    /// replayed; everything else is served from the checkpoint, subject
    /// to the bit-exact fixpoint verification described in the module
    /// docs. Falls back to a cold pass when there is no compatible
    /// checkpoint (different kind/n/m) or the dirty set exceeds half the
    /// rows. The checkpoint is updated to `spec`'s state either way.
    pub fn resimulate(&mut self, spec: &SimSpec) -> FastResult {
        check_spec(spec);
        let compatible = self.ckpt.as_ref().is_some_and(|c| {
            c.spec.kind == spec.kind && c.spec.n() == spec.n() && c.spec.m == spec.m
        });
        if !compatible {
            return self.cold_checkpointed(spec);
        }
        let n = spec.n();
        let m = spec.m;
        let FamilySim { arena, ckpt, dirty, stats } = self;
        dirty.clear();
        dirty.resize(n, false);
        let mut cnt = 0usize;
        {
            let c = ckpt.as_ref().unwrap();
            for i in 0..n {
                if row_differs(&c.spec, spec, i) {
                    dirty[i] = true;
                    cnt += 1;
                }
            }
        }
        if cnt == 0 {
            // bit-identical spec: the checkpoint *is* the answer
            let c = ckpt.as_ref().unwrap();
            arena.cursor.clone_from(&c.cursor);
            arena.busy.clone_from(&c.busy);
            arena.peak_in_flight.clone_from(&c.peak_in_flight);
            stats.incremental_runs += 1;
            let (makespan, bubble_fraction) = finish(arena, n);
            return FastResult { makespan, bubble_fraction };
        }
        loop {
            if 2 * cnt > n {
                stats.fallback_runs += 1;
                let (makespan, bubble_fraction) = run_cold(arena, spec);
                ckpt.as_mut().unwrap().refresh(spec, arena);
                return FastResult { makespan, bubble_fraction };
            }
            let c = ckpt.as_ref().unwrap();
            prefill(arena, c, dirty, n, m);
            let expected: usize = (0..n)
                .filter(|&i| dirty[i])
                .map(|i| ProgramShape::of(spec.kind, n, i, m).len())
                .sum();
            let executed = drain_ready(spec, arena, Some(dirty));
            assert_eq!(
                executed, expected,
                "incremental replay deadlock: {:?} n={n} m={m}",
                spec.kind
            );
            // Fixpoint verification: every clean row fed by a dirty
            // producer must have received bit-identical inputs, else its
            // checkpointed timings are stale and it joins the dirty set.
            let mut grow: Vec<usize> = Vec::new();
            for r in 0..n {
                if dirty[r] {
                    continue;
                }
                let row = r * m;
                let f_stale = r > 0
                    && dirty[r - 1]
                    && !rows_equal(&arena.f_arrival[row..row + m], &c.f_arrival[row..row + m]);
                let b_stale = r + 1 < n
                    && dirty[r + 1]
                    && !rows_equal(&arena.b_arrival[row..row + m], &c.b_arrival[row..row + m]);
                if f_stale || b_stale {
                    grow.push(r);
                }
            }
            if grow.is_empty() {
                break;
            }
            stats.fixpoint_rounds += 1;
            for r in grow {
                dirty[r] = true;
                cnt += 1;
            }
        }
        // Accepted: fold results over the mixed state, then absorb the
        // replayed rows into the checkpoint.
        stats.incremental_runs += 1;
        let (makespan, bubble_fraction) = finish(arena, n);
        let c = ckpt.as_mut().unwrap();
        c.spec.clone_from(spec);
        for i in 0..n {
            if dirty[i] {
                c.cursor[i] = arena.cursor[i];
                c.busy[i] = arena.busy[i];
                c.peak_in_flight[i] = arena.peak_in_flight[i];
                if i + 1 < n {
                    c.f_chan_free[i] = arena.f_chan_free[i];
                }
                if i > 0 {
                    c.b_chan_free[i - 1] = arena.b_chan_free[i - 1];
                }
            }
        }
        for r in 0..n {
            let row = r * m;
            if r > 0 && dirty[r - 1] {
                c.f_arrival[row..row + m].copy_from_slice(&arena.f_arrival[row..row + m]);
            }
            if r + 1 < n && dirty[r + 1] {
                c.b_arrival[row..row + m].copy_from_slice(&arena.b_arrival[row..row + m]);
            }
        }
        FastResult { makespan, bubble_fraction }
    }

    fn cold_checkpointed(&mut self, spec: &SimSpec) -> FastResult {
        self.stats.full_runs += 1;
        let (makespan, bubble_fraction) = run_cold(&mut self.arena, spec);
        match &mut self.ckpt {
            Some(c) => c.refresh(spec, &self.arena),
            None => self.ckpt = Some(Checkpoint::capture(spec, &self.arena)),
        }
        FastResult { makespan, bubble_fraction }
    }
}

/// Does stage `i` need replaying under the new spec? A row owns its
/// compute costs, its exec mode, and the transfer costs of the edges *it
/// produces into* (`fwd_xfer[i]` forward, `bwd_xfer[i-1]` backward) —
/// exactly the parameters `engine::run_core` reads when row `i` executes.
fn row_differs(old: &SimSpec, new: &SimSpec, i: usize) -> bool {
    let n = new.n();
    old.fwd[i].to_bits() != new.fwd[i].to_bits()
        || old.bwd[i].to_bits() != new.bwd[i].to_bits()
        || old.update[i].to_bits() != new.update[i].to_bits()
        || old.exec[i] != new.exec[i]
        || (i + 1 < n && old.fwd_xfer[i].to_bits() != new.fwd_xfer[i].to_bits())
        || (i > 0 && old.bwd_xfer[i - 1].to_bits() != new.bwd_xfer[i - 1].to_bits())
}

fn rows_equal(a: &[f64], b: &[f64]) -> bool {
    a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn check_spec(spec: &SimSpec) {
    let n = spec.n();
    assert!(n >= 1);
    assert_eq!(spec.bwd.len(), n);
    assert_eq!(spec.update.len(), n);
    assert_eq!(spec.exec.len(), n);
    assert_eq!(spec.fwd_xfer.len(), n - 1);
    assert_eq!(spec.bwd_xfer.len(), n - 1);
    assert!(spec.m >= 1);
}

/// Cold batched pass over the whole timeline (mirrors `SimArena::reset`
/// minus the op table and `f_done` matrix, then drains the ready list).
fn run_cold(a: &mut SimArena, spec: &SimSpec) -> (f64, f64) {
    check_spec(spec);
    let n = spec.n();
    let m = spec.m;
    a.f_arrival.clear();
    a.f_arrival.resize(n * m, f64::NAN);
    a.b_arrival.clear();
    a.b_arrival.resize(n * m, f64::NAN);
    // Stage 0's forward inputs are local; the last stage starts backward
    // from its own loss.
    for k in 0..m {
        a.f_arrival[k] = 0.0;
        a.b_arrival[(n - 1) * m + k] = 0.0;
    }
    a.cursor.clear();
    a.cursor.resize(n, 0.0);
    a.busy.clear();
    a.busy.resize(n, 0.0);
    a.pc.clear();
    a.pc.resize(n, 0);
    a.f_chan_free.clear();
    a.f_chan_free.resize(n.saturating_sub(1), 0.0);
    a.b_chan_free.clear();
    a.b_chan_free.resize(n.saturating_sub(1), 0.0);
    a.in_flight.clear();
    a.in_flight.resize(n, 0);
    a.peak_in_flight.clear();
    a.peak_in_flight.resize(n, 0);
    a.ready.clear();
    a.ready.extend(0..n);
    a.queued.clear();
    a.queued.resize(n, true);
    let total: usize = (0..n).map(|i| ProgramShape::of(spec.kind, n, i, m).len()).sum();
    let executed = drain_ready(spec, a, None);
    assert_eq!(executed, total, "schedule deadlock: {:?} n={n} m={m}", spec.kind);
    finish(a, n)
}

/// Makespan and bubble fraction, with the exact folds of `run_core`.
fn finish(a: &SimArena, n: usize) -> (f64, f64) {
    let makespan = a.cursor.iter().cloned().fold(0.0, f64::max);
    let bubble = if makespan > 0.0 {
        (0..n).map(|i| 1.0 - a.busy[i] / makespan).sum::<f64>() / n as f64
    } else {
        0.0
    };
    (makespan, bubble)
}

/// Seed the arena for an incremental replay: clean rows keep their
/// checkpointed timings and their input rows (when the producer is clean
/// too — boundary inputs count as clean); dirty rows restart from zero
/// with NaN'd inputs from dirty producers.
fn prefill(arena: &mut SimArena, c: &Checkpoint, dirty: &[bool], n: usize, m: usize) {
    arena.f_arrival.clear();
    arena.f_arrival.resize(n * m, f64::NAN);
    arena.b_arrival.clear();
    arena.b_arrival.resize(n * m, f64::NAN);
    for r in 0..n {
        let row = r * m;
        if r == 0 || !dirty[r - 1] {
            arena.f_arrival[row..row + m].copy_from_slice(&c.f_arrival[row..row + m]);
        }
        if r + 1 == n || !dirty[r + 1] {
            arena.b_arrival[row..row + m].copy_from_slice(&c.b_arrival[row..row + m]);
        }
    }
    arena.cursor.clone_from(&c.cursor);
    arena.busy.clone_from(&c.busy);
    arena.peak_in_flight.clone_from(&c.peak_in_flight);
    arena.f_chan_free.clone_from(&c.f_chan_free);
    arena.b_chan_free.clone_from(&c.b_chan_free);
    arena.pc.clear();
    arena.pc.resize(n, 0);
    arena.in_flight.clear();
    arena.in_flight.resize(n, 0);
    arena.ready.clear();
    arena.queued.clear();
    arena.queued.resize(n, false);
    for i in 0..n {
        if dirty[i] {
            arena.cursor[i] = 0.0;
            arena.busy[i] = 0.0;
            arena.peak_in_flight[i] = 0;
            if i + 1 < n {
                arena.f_chan_free[i] = 0.0;
            }
            if i > 0 {
                arena.b_chan_free[i - 1] = 0.0;
            }
            arena.ready.push(i);
            arena.queued[i] = true;
        }
    }
}

/// Drain the ready list. With `dirty = Some(mask)` only masked rows are
/// ever (re)queued — clean rows' timings are served from the checkpoint.
fn drain_ready(spec: &SimSpec, a: &mut SimArena, dirty: Option<&[bool]>) -> usize {
    let mut executed = 0usize;
    while let Some(i) = a.ready.pop() {
        a.queued[i] = false;
        executed += exec_stage(spec, a, i, dirty);
    }
    executed
}

/// Run stage `i` forward from its program counter until it blocks on a
/// missing arrival, with the stage's scalar state (cursor, busy,
/// in-flight, channel frees) held in locals for the whole burst. Every
/// timing expression is verbatim from `engine::run_core`; the `f_done`
/// gate is dropped (see module docs). Returns the number of ops executed.
fn exec_stage(spec: &SimSpec, a: &mut SimArena, i: usize, dirty: Option<&[bool]>) -> usize {
    let n = spec.n();
    let m = spec.m;
    let row = i * m;
    let mut cur = a.cursor[i];
    let mut busy = a.busy[i];
    let mut infl = a.in_flight[i];
    let mut peak = a.peak_in_flight[i];
    let mut fch = if i + 1 < n { a.f_chan_free[i] } else { 0.0 };
    let mut bch = if i > 0 { a.b_chan_free[i - 1] } else { 0.0 };
    let mut pc = a.pc[i];
    let pc0 = pc;
    let fd = spec.fwd[i];
    let bd = spec.bwd[i];
    let fbd = fd + bd;
    let ud = spec.update[i];
    let sync = spec.exec[i] == ExecMode::Sync;
    let fx = if i + 1 < n { spec.fwd_xfer[i] } else { 0.0 };
    let bx = if i > 0 { spec.bwd_xfer[i - 1] } else { 0.0 };

    macro_rules! produce_fwd {
        ($mb:expr, $start:expr, $end:expr) => {{
            infl += 1;
            if infl > peak {
                peak = infl;
            }
            if i + 1 < n {
                let arr = if sync {
                    $end.max(fch) + fx
                } else {
                    // streamed during the op when the channel allows
                    $end.max($start.max(fch) + fx)
                };
                fch = arr;
                a.f_arrival[(i + 1) * m + $mb] = arr;
                if !a.queued[i + 1] && dirty.is_none_or(|d| d[i + 1]) {
                    a.queued[i + 1] = true;
                    a.ready.push(i + 1);
                }
            }
        }};
    }
    macro_rules! produce_bwd {
        ($mb:expr, $start:expr, $end:expr) => {{
            infl = infl.saturating_sub(1);
            if i > 0 {
                let arr = if sync {
                    $end.max(bch) + bx
                } else {
                    $end.max($start.max(bch) + bx)
                };
                bch = arr;
                a.b_arrival[(i - 1) * m + $mb] = arr;
                if !a.queued[i - 1] && dirty.is_none_or(|d| d[i - 1]) {
                    a.queued[i - 1] = true;
                    a.ready.push(i - 1);
                }
            }
        }};
    }

    match ProgramShape::of(spec.kind, n, i, m) {
        ProgramShape::OneFOneB { w, m: _, update } => 'blocked: {
            // warm-up forwards
            while pc < w {
                let arr = a.f_arrival[row + pc];
                if arr.is_nan() {
                    break 'blocked;
                }
                let start = cur.max(arr);
                let end = start + fd;
                cur = end;
                busy += fd;
                produce_fwd!(pc, start, end);
                pc += 1;
            }
            // steady 1F1B alternation
            let steady_end = 2 * m - w;
            while pc < steady_end {
                let q = pc - w;
                if q % 2 == 0 {
                    let mb = q / 2;
                    let arr = a.b_arrival[row + mb];
                    if arr.is_nan() {
                        break 'blocked;
                    }
                    let start = cur.max(arr);
                    let end = start + bd;
                    cur = end;
                    busy += bd;
                    produce_bwd!(mb, start, end);
                } else {
                    let mb = w + q / 2;
                    let arr = a.f_arrival[row + mb];
                    if arr.is_nan() {
                        break 'blocked;
                    }
                    let start = cur.max(arr);
                    let end = start + fd;
                    cur = end;
                    busy += fd;
                    produce_fwd!(mb, start, end);
                }
                pc += 1;
            }
            // drain backwards
            while pc < 2 * m {
                let mb = pc - m;
                let arr = a.b_arrival[row + mb];
                if arr.is_nan() {
                    break 'blocked;
                }
                let start = cur.max(arr);
                let end = start + bd;
                cur = end;
                busy += bd;
                produce_bwd!(mb, start, end);
                pc += 1;
            }
            if update && pc == 2 * m {
                // Update is ready at the stage's own cursor
                cur += ud;
                busy += ud;
                pc += 1;
            }
        }
        ProgramShape::GPipe { m: _ } => 'blocked: {
            while pc < m {
                let arr = a.f_arrival[row + pc];
                if arr.is_nan() {
                    break 'blocked;
                }
                let start = cur.max(arr);
                let end = start + fd;
                cur = end;
                busy += fd;
                produce_fwd!(pc, start, end);
                pc += 1;
            }
            while pc < 2 * m {
                let mb = 2 * m - 1 - pc;
                let arr = a.b_arrival[row + mb];
                if arr.is_nan() {
                    break 'blocked;
                }
                let start = cur.max(arr);
                let end = start + bd;
                cur = end;
                busy += bd;
                produce_bwd!(mb, start, end);
                pc += 1;
            }
            if pc == 2 * m {
                cur += ud;
                busy += ud;
                pc += 1;
            }
        }
        ProgramShape::Fbp { o, m: _ } => 'blocked: {
            // forward stream alone until the first backward lands
            let split = o.min(m);
            while pc < split {
                let arr = a.f_arrival[row + pc];
                if arr.is_nan() {
                    break 'blocked;
                }
                let start = cur.max(arr);
                let end = start + fbd;
                cur = end;
                busy += fbd;
                produce_fwd!(pc, start, end);
                pc += 1;
            }
            // concurrent fwd/bwd slots (each costs F+B — static DSPs)
            while pc < m {
                let f_mb = pc;
                let b_mb = pc - o;
                let fa = a.f_arrival[row + f_mb];
                let ba = a.b_arrival[row + b_mb];
                if fa.is_nan() || ba.is_nan() {
                    break 'blocked;
                }
                let start = cur.max(fa.max(ba));
                let end = start + fbd;
                cur = end;
                busy += fbd;
                produce_fwd!(f_mb, start, end);
                produce_bwd!(b_mb, start, end);
                pc += 1;
            }
            // backward-only tail
            let tail_end = m + split;
            while pc < tail_end {
                let mb = o.max(m) + (pc - m) - o;
                let arr = a.b_arrival[row + mb];
                if arr.is_nan() {
                    break 'blocked;
                }
                let start = cur.max(arr);
                let end = start + fbd;
                cur = end;
                busy += fbd;
                produce_bwd!(mb, start, end);
                pc += 1;
            }
            if pc == tail_end {
                cur += ud;
                busy += ud;
                pc += 1;
            }
        }
    }

    let executed = pc - pc0;
    a.pc[i] = pc;
    a.cursor[i] = cur;
    a.busy[i] = busy;
    a.in_flight[i] = infl;
    a.peak_in_flight[i] = peak;
    if i + 1 < n {
        a.f_chan_free[i] = fch;
    }
    if i > 0 {
        a.b_chan_free[i - 1] = bch;
    }
    executed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ScheduleKind;
    use crate::sim::engine::{simulate_fast, simulate_reference};
    use crate::util::prop::{check, ensure, Config};
    use crate::util::rng::Rng;

    fn random_spec(r: &mut Rng, kind: ScheduleKind, n: usize, m: usize) -> SimSpec {
        let mut spec = SimSpec::uniform(kind, n, m, 1.0, 1.0, 0.0, ExecMode::Sync);
        for i in 0..n {
            spec.fwd[i] = 0.01 + r.f64() * 2.0;
            spec.bwd[i] = 0.01 + r.f64() * 3.0;
            spec.update[i] = if r.f64() < 0.5 { 0.0 } else { r.f64() * 0.3 };
            spec.exec[i] = if r.f64() < 0.5 { ExecMode::Sync } else { ExecMode::Async };
        }
        for i in 0..n.saturating_sub(1) {
            spec.fwd_xfer[i] = r.f64() * 1.2;
            spec.bwd_xfer[i] = r.f64() * 1.2;
        }
        spec
    }

    #[test]
    fn batched_cold_matches_fast_and_reference_property() {
        // The table-free batched pass must agree bit-exactly with both
        // simulate_fast and the seed oracle across every kind and mixed
        // per-stage exec modes, with the FamilySim reused across cases.
        let kinds = ScheduleKind::all();
        let mut fam = FamilySim::new();
        let mut arena = SimArena::new();
        check(
            &Config { cases: 150, seed: 0xBA7C4, max_size: 28 },
            |g| {
                let n = g.usize_in(1, 7);
                let m = g.usize_in(1, 28);
                let kind = kinds[g.usize_in(0, kinds.len())];
                let seed = g.usize_in(0, 1 << 30) as u64;
                let mut r = Rng::new(seed);
                random_spec(&mut r, kind, n, m)
            },
            |spec| {
                let reference = simulate_reference(spec);
                let fast = simulate_fast(spec, &mut arena);
                let got = fam.run(spec);
                ensure(
                    got.makespan == reference.makespan,
                    format!("batched makespan {} != ref {}", got.makespan, reference.makespan),
                )?;
                ensure(
                    got.bubble_fraction == reference.bubble_fraction,
                    format!(
                        "batched bubble {} != ref {}",
                        got.bubble_fraction, reference.bubble_fraction
                    ),
                )?;
                ensure(
                    got == fast,
                    format!("batched {got:?} != fast {fast:?}"),
                )?;
                ensure(
                    fam.peak_in_flight() == &reference.peak_in_flight[..],
                    format!(
                        "batched peaks {:?} != ref {:?}",
                        fam.peak_in_flight(),
                        reference.peak_in_flight
                    ),
                )
            },
        );
    }

    #[test]
    fn run_grid_matches_per_candidate_fast() {
        // An M-grid family through one arena pass equals per-candidate
        // simulate_fast, bit for bit, for each kind.
        let mut r = Rng::new(0xFA111);
        for kind in ScheduleKind::all() {
            let n = 5;
            let base = random_spec(&mut r, kind, n, 1);
            let family: Vec<SimSpec> = [2usize, 4, 8, 16, 32]
                .iter()
                .map(|&m| {
                    let mut s = base.clone();
                    s.m = m;
                    s
                })
                .collect();
            let mut fam = FamilySim::new();
            let got = fam.run_grid(&family);
            let mut arena = SimArena::new();
            for (s, g) in family.iter().zip(&got) {
                let fast = simulate_fast(s, &mut arena);
                assert_eq!(*g, fast, "{kind:?} m={}", s.m);
            }
            assert_eq!(fam.stats.full_runs, family.len());
        }
    }

    #[test]
    fn incremental_replays_match_cold_passes_property() {
        // Chains of row mutations replayed incrementally must stay
        // bit-identical to cold reference runs — and the property run as
        // a whole must exercise the incremental, fallback and
        // fixpoint-growth paths (checked after the sweep so a silent
        // always-fallback regression cannot pass).
        let kinds = ScheduleKind::all();
        let mut totals = BatchStats::default();
        check(
            &Config { cases: 60, seed: 0x1C4E_5EED, max_size: 16 },
            |g| {
                let n = g.usize_in(2, 7);
                let m = g.usize_in(1, 16);
                let kind = kinds[g.usize_in(0, kinds.len())];
                let seed = g.usize_in(0, 1 << 30) as u64;
                (kind, n, m, seed)
            },
            |&(kind, n, m, seed)| {
                let mut r = Rng::new(seed);
                let mut spec = random_spec(&mut r, kind, n, m);
                let mut fam = FamilySim::new();
                for step in 0..4 {
                    // mutate 0..=n rows (0 = identical respin; large =
                    // forced fallback)
                    let k = (r.f64() * (n + 1) as f64) as usize;
                    for _ in 0..k {
                        let i = (r.f64() * n as f64) as usize % n;
                        match (r.f64() * 4.0) as usize {
                            0 => spec.fwd[i] = 0.01 + r.f64() * 2.0,
                            1 => spec.bwd[i] = 0.01 + r.f64() * 3.0,
                            2 if i + 1 < n => spec.fwd_xfer[i] = r.f64() * 1.2,
                            _ if i > 0 => spec.bwd_xfer[i - 1] = r.f64() * 1.2,
                            _ => spec.update[i] = r.f64() * 0.3,
                        }
                    }
                    let got = fam.resimulate(&spec);
                    let reference = simulate_reference(&spec);
                    ensure(
                        got.makespan == reference.makespan,
                        format!(
                            "{kind:?} n={n} m={m} step={step}: resim makespan {} != ref {}",
                            got.makespan, reference.makespan
                        ),
                    )?;
                    ensure(
                        got.bubble_fraction == reference.bubble_fraction,
                        format!("{kind:?} n={n} m={m} step={step}: bubble mismatch"),
                    )?;
                    ensure(
                        fam.peak_in_flight() == &reference.peak_in_flight[..],
                        format!("{kind:?} n={n} m={m} step={step}: peaks mismatch"),
                    )?;
                }
                totals.full_runs += fam.stats.full_runs;
                totals.incremental_runs += fam.stats.incremental_runs;
                totals.fallback_runs += fam.stats.fallback_runs;
                totals.fixpoint_rounds += fam.stats.fixpoint_rounds;
                Ok(())
            },
        );
        assert!(totals.full_runs > 0, "no cold passes exercised: {totals:?}");
        assert!(totals.incremental_runs > 0, "no incremental replays exercised: {totals:?}");
        assert!(totals.fallback_runs > 0, "no threshold fallbacks exercised: {totals:?}");
    }

    #[test]
    fn fallback_threshold_boundary() {
        // n=8: exactly 4 dirty rows (2·4 = n) must stay on the
        // incremental path; 5 dirty rows (2·5 > n) must fall back. Both
        // must match the reference bit-exactly.
        let n = 8;
        let m = 6;
        let mut r = Rng::new(0xB0DA);
        let mut spec = random_spec(&mut r, ScheduleKind::OneFOneBSo, n, m);
        for e in spec.exec.iter_mut() {
            *e = ExecMode::Sync;
        }
        let mut fam = FamilySim::new();
        fam.resimulate(&spec); // establish the checkpoint
        assert_eq!(fam.stats.full_runs, 1);

        // Exactly half the rows dirty — update-time changes are truly
        // local (the update op is last in the program and produces
        // nothing), so the dirty set cannot grow and the replay must stay
        // on the incremental path.
        for i in 4..8 {
            spec.update[i] = 0.05 + 0.01 * i as f64;
        }
        let at_limit = fam.resimulate(&spec);
        assert_eq!(fam.stats.incremental_runs, 1, "{:?}", fam.stats);
        assert_eq!(fam.stats.fallback_runs, 0, "{:?}", fam.stats);
        assert_eq!(fam.stats.fixpoint_rounds, 0, "{:?}", fam.stats);
        let reference = simulate_reference(&spec);
        assert_eq!(at_limit.makespan, reference.makespan);
        assert_eq!(at_limit.bubble_fraction, reference.bubble_fraction);

        // One more dirty row crosses the threshold.
        for i in 3..8 {
            spec.fwd[i] += 0.123;
        }
        let past_limit = fam.resimulate(&spec);
        assert_eq!(fam.stats.fallback_runs, 1, "{:?}", fam.stats);
        let reference = simulate_reference(&spec);
        assert_eq!(past_limit.makespan, reference.makespan);
        assert_eq!(past_limit.bubble_fraction, reference.bubble_fraction);
    }

    #[test]
    fn fixpoint_growth_stays_exact() {
        // A compute-cost change on row 0 cascades into downstream rows'
        // arrivals; the fixpoint check must grow the dirty set (or fall
        // back) rather than serve stale checkpointed timings.
        let n = 6;
        let m = 8;
        let mut r = Rng::new(0xF1F0);
        let mut spec = random_spec(&mut r, ScheduleKind::OneFOneBAs, n, m);
        let mut fam = FamilySim::new();
        fam.resimulate(&spec);
        spec.fwd[0] *= 3.0;
        let got = fam.resimulate(&spec);
        let reference = simulate_reference(&spec);
        assert_eq!(got.makespan, reference.makespan);
        assert_eq!(got.bubble_fraction, reference.bubble_fraction);
        assert_eq!(fam.peak_in_flight(), &reference.peak_in_flight[..]);
        assert!(
            fam.stats.fixpoint_rounds > 0 || fam.stats.fallback_runs > 0,
            "cascading change neither grew the dirty set nor fell back: {:?}",
            fam.stats
        );
    }

    #[test]
    fn begin_family_releases_capacity_between_families() {
        // A big family grows the arena; starting a much smaller family
        // must shrink it (the SHRINK_HYSTERESIS policy over
        // SimArena::shrink_to).
        let mut fam = FamilySim::new();
        let big = SimSpec::uniform(ScheduleKind::OneFOneBSo, 16, 512, 1.0, 2.0, 0.1, ExecMode::Sync);
        fam.run_grid(std::slice::from_ref(&big));
        assert!(fam.arena().cells_capacity() >= 16 * 512);
        let small = SimSpec::uniform(ScheduleKind::GPipe, 2, 4, 1.0, 1.0, 0.2, ExecMode::Sync);
        let got = fam.run_grid(std::slice::from_ref(&small))[0];
        assert!(
            fam.arena().cells_capacity() < 16 * 512 / SHRINK_HYSTERESIS,
            "capacity {} not released",
            fam.arena().cells_capacity()
        );
        let mut arena = SimArena::new();
        assert_eq!(got, simulate_fast(&small, &mut arena));
    }

    #[test]
    fn resimulate_on_shape_change_recovers_with_cold_pass() {
        // kind / n / m changes invalidate the checkpoint; resimulate must
        // transparently run cold and stay exact.
        let mut fam = FamilySim::new();
        let mut arena = SimArena::new();
        for spec in [
            SimSpec::uniform(ScheduleKind::OneFOneBSo, 4, 8, 1.0, 2.0, 0.1, ExecMode::Sync),
            SimSpec::uniform(ScheduleKind::OneFOneBSo, 4, 12, 1.0, 2.0, 0.1, ExecMode::Sync),
            SimSpec::uniform(ScheduleKind::GPipe, 4, 12, 1.0, 2.0, 0.1, ExecMode::Sync),
            SimSpec::uniform(ScheduleKind::GPipe, 6, 12, 1.0, 2.0, 0.1, ExecMode::Sync),
        ] {
            assert_eq!(fam.resimulate(&spec), simulate_fast(&spec, &mut arena));
        }
        assert_eq!(fam.stats.full_runs, 4);
        assert_eq!(fam.stats.incremental_runs, 0);
    }

    #[test]
    fn single_stage_pipelines_work_in_both_modes() {
        let spec = SimSpec::uniform(ScheduleKind::OneFOneBSno, 1, 4, 1.0, 2.0, 0.0, ExecMode::Sync);
        let mut fam = FamilySim::new();
        let mut arena = SimArena::new();
        assert_eq!(fam.run(&spec), simulate_fast(&spec, &mut arena));
        assert_eq!(fam.resimulate(&spec), simulate_fast(&spec, &mut arena));
        let mut tweaked = spec.clone();
        tweaked.fwd[0] = 1.5;
        // n=1: any dirty row exceeds the n/2 threshold → fallback
        assert_eq!(fam.resimulate(&tweaked), simulate_fast(&tweaked, &mut arena));
        assert_eq!(fam.stats.fallback_runs, 1);
    }
}
