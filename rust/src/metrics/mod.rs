//! Lightweight telemetry: named counters, timers and throughput meters
//! for the coordinator (logged at the end of runs and by benches).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// A metrics registry (cheap enough to share behind a Mutex — updates are
/// off the per-op hot path; per-op timing uses local `Stopwatch`es that
/// flush once).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    sums: BTreeMap<String, (f64, u64)>, // sum, count
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment a counter.
    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Record an observation (e.g. seconds) into a mean series.
    pub fn observe(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.sums.entry(name.to_string()).or_insert((0.0, 0));
        e.0 += value;
        e.1 += 1;
    }

    /// Counter value.
    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Mean of an observation series (0 if empty).
    pub fn mean(&self, name: &str) -> f64 {
        let g = self.inner.lock().unwrap();
        match g.sums.get(name) {
            Some(&(s, n)) if n > 0 => s / n as f64,
            _ => 0.0,
        }
    }

    /// Sum of an observation series.
    pub fn sum(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().sums.get(name).map(|&(s, _)| s).unwrap_or(0.0)
    }

    /// Render a sorted report.
    pub fn report(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, &(s, n)) in &g.sums {
            if n > 0 {
                out.push_str(&format!("{k}: mean {:.6} (n={n}, sum {:.4})\n", s / n as f64, s));
            }
        }
        out
    }
}

/// Scope timer that reports elapsed seconds into a `Metrics` series.
pub struct Stopwatch<'a> {
    metrics: &'a Metrics,
    name: &'a str,
    start: Instant,
}

impl<'a> Stopwatch<'a> {
    /// Start timing `name`.
    pub fn start(metrics: &'a Metrics, name: &'a str) -> Stopwatch<'a> {
        Stopwatch { metrics, name, start: Instant::now() }
    }
}

impl Drop for Stopwatch<'_> {
    fn drop(&mut self) {
        self.metrics.observe(self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_means() {
        let m = Metrics::new();
        m.inc("ops", 2);
        m.inc("ops", 3);
        assert_eq!(m.counter("ops"), 5);
        m.observe("t", 1.0);
        m.observe("t", 3.0);
        assert_eq!(m.mean("t"), 2.0);
        assert_eq!(m.sum("t"), 4.0);
        assert_eq!(m.counter("missing"), 0);
        assert_eq!(m.mean("missing"), 0.0);
    }

    #[test]
    fn stopwatch_records() {
        let m = Metrics::new();
        {
            let _s = Stopwatch::start(&m, "lap");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(m.mean("lap") >= 0.004);
    }

    #[test]
    fn report_contains_entries() {
        let m = Metrics::new();
        m.inc("steps", 1);
        m.observe("loss", 2.5);
        let r = m.report();
        assert!(r.contains("steps: 1"));
        assert!(r.contains("loss"));
    }
}
