//! E4 — Table 4: maximum (L, W) of GNMT-L trainable on 1/2/4/8 V100s
//! (16 GB) under DP, PipeDream, GPipe and BaPipe. B = 32 per GPU and
//! M = 2 × stages for the intra-batch pipelines, BaPipe on 1F1B-SNO —
//! exactly the paper's setting. Binary-searches the largest even L whose
//! memory plan fits.
//!
//! Run: `cargo bench --bench table4`

use bapipe::cluster::presets;
use bapipe::model::zoo;
use bapipe::partition::memfit::{dp_memory_bytes, MemoryModel};
use bapipe::partition::{balanced_partition, interlayer};
use bapipe::profile::analytical;
use bapipe::schedule::ScheduleKind;
use bapipe::util::benchkit::print_table;
use bapipe::util::fmt_params;

/// Does GNMT-L with `l` layers fit under the given framework on n GPUs?
fn fits(framework: &str, l: u64, n: usize) -> bool {
    let net = zoo::gnmt_l(l);
    let cl = presets::v100_cluster(n);
    let prof = analytical::profile(&net, &cl);
    let b = 32.0;
    let m = 2 * n; // micro-batches = 2x stages (paper setting)
    let micro = b * n as f64 / m as f64;
    match framework {
        "dp" => {
            let mm = MemoryModel::data_parallel();
            dp_memory_bytes(&prof, &mm, b) <= mm.usable(cl.devices[0].mem_capacity)
        }
        "pipedream" => {
            // PipeDream's own partitioner (no memory term), weight
            // stashing memory; per-device batch B flows whole.
            let cuts = net.legal_cuts();
            let Ok(part) = interlayer::dp_optimal(&prof, &cl, &cuts, b, None) else {
                return false;
            };
            let mm = MemoryModel::default();
            (0..n).all(|i| {
                bapipe::partition::memfit::stage_memory_bytes(
                    &prof,
                    &mm,
                    ScheduleKind::PipeDream,
                    false,
                    n,
                    i,
                    part.stage(i),
                    b,
                    1,
                ) <= mm.usable(cl.devices[i].mem_capacity)
            })
        }
        "gpipe" => {
            balanced_partition(&net, &cl, &prof, ScheduleKind::GPipe, micro, m).is_ok()
        }
        "bapipe" => {
            balanced_partition(&net, &cl, &prof, ScheduleKind::OneFOneBSno, micro, m).is_ok()
        }
        _ => unreachable!(),
    }
}

/// Largest even L that fits. GNMT-L needs enough layers to cut into `n`
/// stages, so the search seeds at the smallest partitionable size.
fn max_l(framework: &str, n: usize) -> u64 {
    let seed = (2 * n as u64).max(2); // n stages need >= n cuttable layers
    let mut lo = seed;
    if !fits(framework, seed, n) {
        return 0;
    }
    let mut hi = 514u64;
    while hi - lo > 2 {
        let mid = (lo + hi) / 4 * 2; // even midpoint
        let mid = mid.clamp(lo + 2, hi - 2);
        if fits(framework, mid, n) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

fn main() {
    let mut rows = Vec::new();
    for framework in ["dp", "pipedream", "gpipe", "bapipe"] {
        let mut row = vec![framework.to_string()];
        for n in [1usize, 2, 4, 8] {
            let l = if n == 1 && framework != "dp" {
                // single device: every framework degenerates to DP
                max_l("dp", 1)
            } else {
                max_l(framework, n)
            };
            let w = if l >= 2 { zoo::gnmt_l(l).total_params() } else { 0 };
            row.push(format!("({l}, {})", fmt_params(w)));
        }
        rows.push(row);
    }
    print_table(
        "Table 4: maximum (L, W) of GNMT-L per framework (16 GB V100s, B=32, M=2N)",
        &["framework", "1 V100", "2 V100", "4 V100", "8 V100"],
        &rows,
    );
    println!(
        "\nPaper shapes to check: DP and PipeDream flat in N (weight stashing keeps\n\
         stage 0 at ~full model memory); GPipe grows but stores whole-mini-batch\n\
         activations; BaPipe grows fastest — paper reports 4x DP and 2x GPipe at\n\
         8 GPUs ((158, 1.78B) vs (32, 445.6M) / (74, 886.4M))."
    );
}
