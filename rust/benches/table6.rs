//! E5+E6 — Tables 5 and 6: FPGA platform parameters and ResNet-50
//! training batch time, BaPipe vs DP, on 4×VCU118 / 2×VCU129+2×VCU118 /
//! 4×VCU129 (FPDeep-style analytical profiles, fp16, micro-batch 1,
//! mini-batch 128 — the paper's Section 4.3 setting).
//!
//! Run: `cargo bench --bench table6`

use bapipe::cluster::presets;
use bapipe::explorer::build_spec_plan;
use bapipe::model::zoo;
use bapipe::partition::balanced_partition;
use bapipe::profile::analytical;
use bapipe::schedule::ScheduleKind;
use bapipe::sim::{dp, engine::simulate};
use bapipe::util::benchkit::print_table;

fn main() {
    // Table 5 — platform parameters (presets carry them).
    let a = presets::vcu118();
    let b = presets::vcu129();
    print_table(
        "Table 5: FPGA platform parameters",
        &["platform", "DSP slices", "on-chip RAM", "DDR4 BW", "peak (fp16)"],
        &[
            vec![
                a.name.clone(),
                a.dsp_slices.to_string(),
                format!("{:.1} Mb", a.onchip_capacity as f64 * 8.0 / 1e6),
                format!("{:.0} GB/s", a.mem_bw / 1e9),
                format!("{:.2} TFLOPS", a.peak_flops / 1e12),
            ],
            vec![
                b.name.clone(),
                b.dsp_slices.to_string(),
                format!("{:.1} Mb", b.onchip_capacity as f64 * 8.0 / 1e6),
                format!("{:.0} GB/s", b.mem_bw / 1e9),
                format!("{:.2} TFLOPS", b.peak_flops / 1e12),
            ],
        ],
    );

    // Table 6 — ResNet-50 batch time speedup over DP.
    let net = zoo::resnet50(224);
    let mini = 128usize; // mini-batch size (paper)
    let micro = 1.0; // micro-batch 1 (paper)
    let mut rows = Vec::new();
    for boards in [
        vec!["VCU118"; 4],
        vec!["VCU129", "VCU129", "VCU118", "VCU118"],
        vec!["VCU129"; 4],
    ] {
        let cl = presets::fpga_cluster(&boards);
        let prof = analytical::profile(&net, &cl);
        // DP: per-device batch = mini/N. A DP replica computes ALL layers
        // on one board; the full weight set (~51 MB fp16) exceeds the
        // usable BRAM/URAM, so under the FPDeep fine-grained dataflow each
        // sample re-streams the working weights from DDR (the paper: "DP
        // has to store weights in DDR due to the size limits").
        // Shards weighted by device speed (fair heterogeneous DP), so
        // compute = mini / Σ_d 1/t_d with t_d the per-sample fwd+bwd time.
        let l = prof.n_layers();
        let inv_sum: f64 = (0..cl.len())
            .map(|d| 1.0 / (prof.fwd_time(d, 0, l, 1.0) + prof.bwd_time(d, 0, l, 1.0)))
            .sum();
        let compute = mini as f64 / inv_sum;
        let w_bytes = prof.param_bytes(0, l) as f64;
        let spills = cl
            .devices
            .iter()
            .any(|d| w_bytes > 0.75 * d.onchip_capacity as f64);
        let stream = if spills {
            // full weight set re-streamed from DDR each pass (fwd read +
            // bwd read & gradient write)
            3.0 * w_bytes
                / cl.devices.iter().map(|d| d.mem_bw).fold(f64::INFINITY, f64::min)
        } else {
            0.0
        };
        let b_dev = mini as f64 / cl.len() as f64;
        let dp_time = compute + stream + dp::minibatch(&prof, &cl, b_dev).allreduce;

        // BaPipe: FBP-AS (the paper's automatic choice), micro-batch 1;
        // per-stage weights (~13 MB) stay resident on-chip.
        let m = mini; // micro-batch 1 → M = mini-batch size
        let plan = balanced_partition(&net, &cl, &prof, ScheduleKind::FbpAs, micro, m)
            .expect("partition feasible");
        let spec = build_spec_plan(&prof, &cl, &plan, ScheduleKind::FbpAs, false, micro, m);
        let ba_time = simulate(&spec).makespan;

        rows.push(vec![
            cl.describe(),
            format!("{:.1} ms", dp_time * 1e3),
            format!("{:.1} ms", ba_time * 1e3),
            format!("{:.2}x", dp_time / ba_time),
            "FBP-AS".to_string(),
        ]);
    }
    print_table(
        "Table 6: ResNet-50 batch time, BaPipe vs DP on FPGA clusters (mini=128, micro=1, fp16)",
        &["cluster", "DP batch time", "BaPipe batch time", "speedup", "schedule"],
        &rows,
    );
    println!(
        "\nPaper shapes to check: modest speedups (paper: 1x / 1.05x / 1.14x),\n\
         increasing with VCU129 count (more on-chip RAM → more weights resident);\n\
         BaPipe chooses FBP-AS (utilization at micro-batch 1)."
    );
}
