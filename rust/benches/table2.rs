//! E2 — Table 2: 1F1B-SNO vs 1F1B-SO (synchronous scheduling on GPU
//! clusters): closed forms + DES cross-check, sweeping M to show SNO's
//! non-overlap penalty growing ∝ M while SO pays only (N-1)·2SR.
//!
//! Run: `cargo bench --bench table2`

use bapipe::cluster::ExecMode;
use bapipe::schedule::analytical::*;
use bapipe::schedule::ScheduleKind;
use bapipe::sim::engine::{simulate, SimSpec};
use bapipe::util::benchkit::print_table;

fn main() {
    let (f, b) = (1.0e-3, 1.0e-3);
    let a = 4.0e6;
    let w = 16.0e6;
    let mut rows = Vec::new();
    for (m, n, sr) in [
        (8usize, 3usize, 0.25e-3),
        (16, 3, 0.25e-3),
        (32, 3, 0.25e-3),
        (16, 4, 0.10e-3),
        (64, 4, 0.10e-3),
    ] {
        let s = Symbols { m, n, f, b, sr, a, w };
        for kind in [ScheduleKind::OneFOneBSno, ScheduleKind::OneFOneBSo] {
            let t = minibatch_time(kind, &s);
            let spec = SimSpec::uniform(kind, n, m, f, b, sr, ExecMode::Sync);
            let des = simulate(&spec);
            rows.push(vec![
                format!("M={m},N={n},SR={:.2}ms", sr * 1e3),
                kind.label().to_string(),
                format!("{:.2} ms", t * 1e3),
                format!("{:.2} ms", des.makespan * 1e3),
                format!("{:.1}%", bubble_fraction(kind, &s) * 100.0),
                format!("{:.1} MB", features_memory(kind, &s, 1) / 1e6),
                format!("{}x", des.peak_in_flight[0]),
                format!("{:.1} GB/s", demand_bandwidth(kind, &s) / 1e9),
            ]);
        }
    }
    print_table(
        "Table 2: 1F1B-SNO vs 1F1B-SO (paper closed forms + DES cross-check)",
        &[
            "case", "schedule", "mini-batch(paper)", "mini-batch(DES)", "bubble",
            "feat mem@stage1", "DES in-flight@1", "demand BW",
        ],
        &rows,
    );

    // The headline qualitative claim: SNO's extra bubble is ∝ M.
    let gap = |m: usize| {
        let mk = |kind| {
            simulate(&SimSpec::uniform(kind, 3, m, f, b, 0.4e-3, ExecMode::Sync)).makespan
        };
        mk(ScheduleKind::OneFOneBSno) - mk(ScheduleKind::OneFOneBSo)
    };
    println!("\nSNO-SO gap growth (DES): M=8 -> {:.2} ms, M=32 -> {:.2} ms, M=128 -> {:.2} ms",
        gap(8) * 1e3, gap(32) * 1e3, gap(128) * 1e3);
    println!("SO's cost: 2x warm-up activations (feature memory column).");
}
