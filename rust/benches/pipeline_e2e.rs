//! E11 — real-engine benchmark: steps/sec and tokens/sec per schedule on
//! the tiny artifact bundle, plus the per-stage time breakdown that the
//! §Perf pass optimizes. Skips gracefully when artifacts are missing.
//!
//! Run: `cargo bench --bench pipeline_e2e`   (needs `make artifacts`)

use bapipe::config::TrainConfig;
use bapipe::pipeline::{dp_engine, training};
use bapipe::util::benchkit::print_table;
use std::path::PathBuf;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/lm1m-s2-b2-jnp");
    if !dir.join("manifest.json").exists() {
        println!("pipeline_e2e: artifacts not built (`make artifacts`), skipping");
        return;
    }
    let dir = dir.to_str().unwrap().to_string();
    let steps = 8usize;
    let m = 8usize;
    let mut rows = Vec::new();
    for schedule in ["gpipe", "1f1b", "1f1b-so", "fbp", "pipedream"] {
        let cfg = TrainConfig {
            artifacts: dir.clone(),
            schedule: schedule.into(),
            m,
            steps,
            lr: 1e-3,
            seed: 1,
            branch: 8,
            noise: 0.1,
            log_every: steps,
        };
        let rep = training::train(&cfg).expect(schedule);
        let (f, b, o, stall): (f64, f64, f64, f64) = rep
            .per_stage_means
            .iter()
            .fold((0.0, 0.0, 0.0, 0.0), |a, p| (a.0 + p.0, a.1 + p.1, a.2 + p.2, a.3 + p.3));
        rows.push(vec![
            schedule.to_string(),
            format!("{:.1}", rep.tokens_per_sec),
            format!("{:.1} ms", rep.total_secs / steps as f64 * 1e3),
            format!("{:.1} ms", f * 1e3),
            format!("{:.1} ms", b * 1e3),
            format!("{:.1} ms", o * 1e3),
            format!("{:.1} ms", stall * 1e3),
            format!("{:.3}", rep.final_loss),
        ]);
    }
    // DP baseline on the same artifacts.
    let cfg = TrainConfig {
        artifacts: dir.clone(),
        schedule: "dp".into(),
        m: 1,
        steps,
        lr: 1e-3,
        seed: 1,
        branch: 8,
        noise: 0.1,
        log_every: steps,
    };
    let rep = dp_engine::train_dp(&cfg, 2).expect("dp");
    rows.push(vec![
        "dp (2 replicas)".into(),
        format!("{:.1}", rep.tokens_per_sec),
        format!("{:.1} ms", rep.total_secs / steps as f64 * 1e3),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.3}", rep.final_loss),
    ]);
    print_table(
        &format!("Real engine: lm1m artifacts, {steps} steps, M={m} (single CPU core)"),
        &[
            "schedule", "tokens/s", "step time", "Σfwd", "Σbwd", "Σopt", "Σstall", "final loss",
        ],
        &rows,
    );
    println!(
        "\nNote: on one CPU core pipeline stages time-share, so tokens/s measures\n\
         engine overhead + schedule bookkeeping, not parallel speedup; wall-clock\n\
         parallel claims come from the calibrated DES (tables 1-4, 6)."
    );
}
