//! E7–E10 — Figures 2, 4, 5, 6: ASCII timeline regenerations from the
//! discrete-event simulator.
//!
//! Run: `cargo bench --bench figures`

use bapipe::cluster::ExecMode;
use bapipe::schedule::ScheduleKind;
use bapipe::sim::engine::{simulate, SimSpec};
use bapipe::sim::timeline;

fn show(title: &str, spec: &SimSpec, n: usize, order_only: bool) {
    let r = simulate(spec);
    println!("\n== {title} ==");
    println!(
        "makespan {:.2} (bubble {:.1}%)",
        r.makespan,
        r.bubble_fraction * 100.0
    );
    if order_only {
        print!("{}", timeline::render_order(&r, n));
    } else {
        print!("{}", timeline::render(&r, n, 110));
    }
}

fn main() {
    // Fig. 2(a): intra-batch pipeline parallelism (GPipe), 4 stages, M=4.
    show(
        "Fig. 2(a): intra-batch (GPipe fill-drain), 4 accel, M=4",
        &SimSpec::uniform(ScheduleKind::GPipe, 4, 4, 1.0, 2.0, 0.0, ExecMode::Sync),
        4,
        false,
    );
    // Fig. 2(b): inter-batch pipeline (PipeDream 1F1B across mini-batches).
    show(
        "Fig. 2(b): inter-batch (PipeDream 1F1B), 4 accel, 8 mini-batches",
        &SimSpec::uniform(ScheduleKind::PipeDream, 4, 8, 1.0, 2.0, 0.0, ExecMode::Sync),
        4,
        false,
    );
    // Fig. 4: async vs sync execution, 2 accelerators (comm visible through
    // the arrival gap in the sync case).
    show(
        "Fig. 4(a): asynchronous execution (streamed comm), 2 accel",
        &SimSpec::uniform(ScheduleKind::OneFOneBAs, 2, 4, 1.0, 1.0, 0.6, ExecMode::Async),
        2,
        false,
    );
    show(
        "Fig. 4(b): synchronous execution (comm after compute), 2 accel",
        &SimSpec::uniform(ScheduleKind::OneFOneBSno, 2, 4, 1.0, 1.0, 0.6, ExecMode::Sync),
        2,
        false,
    );
    // Fig. 5: 1F1B-AS and FBP-AS, 3 accelerators, M=8.
    show(
        "Fig. 5(a): 1F1B-AS, 3 accel, M=8 (op order; cf. warm-up depths 3/2/1)",
        &SimSpec::uniform(ScheduleKind::OneFOneBAs, 3, 8, 1.0, 1.0, 0.1, ExecMode::Async),
        3,
        true,
    );
    show(
        "Fig. 5(b): FBP-AS, 3 accel, M=8 (op order; * = concurrent fwd/bwd slot)",
        &SimSpec::uniform(ScheduleKind::FbpAs, 3, 8, 1.0, 1.0, 0.1, ExecMode::Async),
        3,
        true,
    );
    // Fig. 6: 1F1B-SNO vs 1F1B-SO, 3 accelerators.
    show(
        "Fig. 6(a): 1F1B-SNO, 3 accel, M=6, SR=0.4 (comm on the critical path)",
        &SimSpec::uniform(ScheduleKind::OneFOneBSno, 3, 6, 1.0, 1.0, 0.4, ExecMode::Sync),
        3,
        false,
    );
    show(
        "Fig. 6(b): 1F1B-SO, 3 accel, M=6, SR=0.4 (doubled warm-up overlaps comm)",
        &SimSpec::uniform(ScheduleKind::OneFOneBSo, 3, 6, 1.0, 1.0, 0.4, ExecMode::Sync),
        3,
        false,
    );
}
