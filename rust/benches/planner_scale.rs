//! 64-stage planner stress bench (the ROADMAP "Scale" item): DES
//! fast-path vs the seed simulator at n=8 / m=256, the batched-family
//! sweep vs per-candidate `simulate_fast` on a 1024-stage synthetic
//! pipeline with M up to 4096 (`sim_batch`), the partition DP
//! trajectory (seed reference loop → prefix tables → prefix + monotone
//! crossing search) on the 64-stage cut set, the phase-A balance-seed
//! fan-out, the end-to-end exploration at jobs ∈ {1, 8} on a 64-stage
//! synthetic cluster with M up to 512, the elastic `replan` line —
//! warm-started scenario replay vs cold re-exploration on a 16-device
//! loss/degrade/straggler script, with migration bytes — and the
//! `migration_overlap` line: the challenger's state transfers placed
//! into a 2BW drain's bubbles vs the drain-and-copy fallback on the same
//! 16-device straggler — plus the `verify_overhead` line: the static
//! program certificate (`verify::check_program`) vs one `simulate_fast`
//! pass on the 64-stage preset, emitting the measured perf trajectory to
//! `BENCH_planner.json` at the repository root so later PRs can track
//! regressions.
//!
//! Run: `cargo bench --bench planner_scale`
//! CI smoke (small model, one iteration): `BAPIPE_BENCH_QUICK=1 cargo
//! bench --bench planner_scale` (or pass `--quick`).
//! Output override: `BAPIPE_BENCH_OUT=path.json`.

use bapipe::cluster::mutate::{self, ClusterEvent, Scenario};
use bapipe::cluster::{presets, ExecMode};
use bapipe::model::zoo;
use bapipe::partition::interlayer::{
    dp_optimal_prefix, dp_optimal_rc, dp_optimal_reference, max_stage_time,
};
use bapipe::partition::memfit::MemoryModel;
use bapipe::planner::space::permuted_view;
use bapipe::planner::{self, elastic, migrate, Choice, EvalCache, Options, Outcome, SearchSpace};
use bapipe::profile::{analytical, RangeCost};
use bapipe::schedule::{generators, ScheduleKind};
use bapipe::sim::batch::FamilySim;
use bapipe::sim::engine::{simulate_fast, simulate_reference, SimArena, SimSpec};
use bapipe::util::benchkit::bench;
use bapipe::util::json::{obj, Json};
use bapipe::verify;

fn main() {
    let quick = std::env::var("BAPIPE_BENCH_QUICK").map(|v| v != "0").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");

    // ---- DES micro: the seed polling simulator vs the trace-free SoA
    // fast path, on the micro.rs working-set shape (8 stages, 256
    // micro-batches).
    let (warm, iters) = if quick { (1, 5) } else { (3, 30) };
    let spec =
        SimSpec::uniform(ScheduleKind::OneFOneBSo, 8, 256, 1e-3, 2e-3, 0.2e-3, ExecMode::Sync);
    let total_ops: usize =
        (0..8).map(|i| generators::program(spec.kind, 8, i, 256).ops.len()).sum();
    let seed = bench("des/seed(reference) 1f1b-so n=8 m=256", warm, iters, || {
        std::hint::black_box(simulate_reference(&spec).makespan);
    });
    let mut arena = SimArena::new();
    let fast = bench("des/fast 1f1b-so n=8 m=256", warm, iters, || {
        std::hint::black_box(simulate_fast(&spec, &mut arena).makespan);
    });
    let seed_ns_per_op = seed.p50 * 1e9 / total_ops as f64;
    let fast_ns_per_op = fast.p50 * 1e9 / total_ops as f64;
    let des_speedup = seed.p50 / fast.p50;
    println!(
        "  des speedup (seed/fast): {des_speedup:.2}x  \
         ({seed_ns_per_op:.1} -> {fast_ns_per_op:.1} ns/op)"
    );

    // ---- Static verifier overhead on the 64-stage preset: the full
    // program certificate (per-stage dependency walk, transfer FIFO
    // proof, deadlock topological sort, staleness bound, stash-depth
    // derivation) vs ONE `simulate_fast` pass of the same shape — the
    // per-candidate price the `cfg(debug_assertions)` planner gate pays.
    let vn = 64usize;
    let vm = 512usize;
    let vspec =
        SimSpec::uniform(ScheduleKind::OneFOneBSo, vn, vm, 1e-3, 2e-3, 0.1e-3, ExecMode::Sync);
    let mut varena = SimArena::new();
    let v_des = bench(&format!("verify/one-des-pass 1f1b-so n={vn} m={vm}"), warm, iters, || {
        std::hint::black_box(simulate_fast(&vspec, &mut varena).makespan);
    });
    let v_check = bench(&format!("verify/check_program 1f1b-so n={vn} m={vm}"), warm, iters, || {
        let r = verify::check_program(ScheduleKind::OneFOneBSo, vn, vm);
        assert!(r.is_clean(), "{}", r.render("bench program"));
        std::hint::black_box(r.violations.len());
    });
    let verify_ratio = v_check.p50 / v_des.p50;
    println!(
        "  verify overhead (check_program / one DES pass) n={vn} m={vm}: {verify_ratio:.2}x"
    );

    // ---- Batched-family DES at 1024-stage scale: one M-grid family
    // swept through a single `FamilySim` arena pass (table-free
    // closed-form programs) vs per-candidate `simulate_fast`, which
    // rebuilds the flat op table for every candidate — ~8.4M ops of
    // build-and-stream traffic per candidate at n=1024, M=4096.
    let (bn, bm_grid): (usize, Vec<usize>) =
        if quick { (128, vec![32, 64, 128]) } else { (1024, vec![512, 1024, 2048, 4096]) };
    let bm_max = *bm_grid.last().unwrap();
    let mut base =
        SimSpec::uniform(ScheduleKind::OneFOneBSo, bn, 1, 1e-3, 2e-3, 0.1e-3, ExecMode::Sync);
    for i in 0..bn {
        // deterministic heterogeneity — a few device classes, so the
        // ready list stays busy instead of lock-stepping
        base.fwd[i] = 1e-3 * (1.0 + 0.05 * (i % 5) as f64);
        base.bwd[i] = 2e-3 * (1.0 + 0.04 * (i % 7) as f64);
    }
    for i in 0..bn - 1 {
        base.fwd_xfer[i] = 0.1e-3 * (1.0 + 0.5 * (i % 3) as f64);
        base.bwd_xfer[i] = base.fwd_xfer[i];
    }
    let family: Vec<SimSpec> = bm_grid
        .iter()
        .map(|&m| {
            let mut s = base.clone();
            s.m = m;
            s
        })
        .collect();
    // Bit-exactness re-checked at bench scale, once, outside the timed
    // region (the property suite covers the small shapes).
    let mut fam = FamilySim::new();
    {
        let batch_res = fam.run_grid(&family);
        let mut check_arena = SimArena::new();
        for (s, b) in family.iter().zip(&batch_res) {
            assert_eq!(
                *b,
                simulate_fast(s, &mut check_arena),
                "batched pass diverged from simulate_fast at n={bn} m={}",
                s.m
            );
        }
    }
    let (bw, bi) = if quick { (0, 2) } else { (1, 3) };
    let mut grid_arena = SimArena::new();
    let sweep_fast =
        bench(&format!("sim/fast m-grid n={bn} m_max={bm_max}"), bw, bi, || {
            for s in &family {
                std::hint::black_box(simulate_fast(s, &mut grid_arena).makespan);
            }
        });
    let sweep_batch =
        bench(&format!("sim/batch m-grid n={bn} m_max={bm_max}"), bw, bi, || {
            std::hint::black_box(fam.run_grid(&family).len());
        });
    let batch_speedup = sweep_fast.p50 / sweep_batch.p50;
    println!("  sim_batch speedup (fast/batched) n={bn} m_max={bm_max}: {batch_speedup:.2}x");

    // ---- 64-stage synthetic cluster: GNMT-L chain on 64 V100 slots.
    let stages = 64usize;
    let model = if quick { "gnmt-l64" } else { "gnmt-l128" };
    let net = zoo::by_name(model).unwrap();
    let cl = presets::v100_cluster(stages);
    let prof = analytical::profile(&net, &cl);
    let m_grid: Vec<usize> =
        if quick { vec![64, 512] } else { vec![8, 16, 32, 64, 128, 256, 512] };
    let mk_opts = |jobs: usize| Options {
        batch_per_device: 8.0, // global mini-batch 512 → M=512 is micro-batch 1
        samples_per_epoch: 4096,
        m_candidates: m_grid.clone(),
        consider_dp: false,
        permute_devices: true, // homogeneous → identity ordering (noted)
        jobs,
        ..Default::default()
    };

    // Phase A in isolation: the balance-seed DPs + memory fine-tunes that
    // `EvalCache::prewarm` fans out per distinct (perm, micro) work item.
    let space = SearchSpace::bapipe(&net, &cl, &prof, &mk_opts(1));
    let views: Vec<_> =
        space.device_orders.iter().map(|o| permuted_view(&cl, &prof, o)).collect();
    let cands = space.candidates(stages);
    let global = 8.0 * stages as f64;
    let (aw, ai) = if quick { (0, 1) } else { (1, 5) };
    let pa1 = bench("planner/phase-a 64-stage jobs=1", aw, ai, || {
        let mut cache = EvalCache::new();
        cache.prewarm(&net, &views, &cands, global, 1);
        std::hint::black_box(cache.misses);
    });
    let pa8 = bench("planner/phase-a 64-stage jobs=8", aw, ai, || {
        let mut cache = EvalCache::new();
        cache.prewarm(&net, &views, &cands, global, 8);
        std::hint::black_box(cache.misses);
    });

    // Partition DP in isolation on the 64-stage scenario: the seed's
    // O(N·C²·L) triple loop (retained as `dp_optimal_reference`, the
    // bit-exactness oracle) vs the prefix-table O(N·C²) loop vs the
    // prefix + monotone-crossing O(N·C·log C) path `dp_optimal` now runs.
    let cuts = net.legal_cuts();
    let rc = RangeCost::build(&prof);
    let dp_micro = 8.0;
    let (dw, di) = if quick { (0, 2) } else { (1, 8) };
    let dp_ref = bench("partition/dp 64-stage reference", dw, di, || {
        std::hint::black_box(
            dp_optimal_reference(&prof, &cl, &cuts, dp_micro, None).unwrap(),
        );
    });
    let dp_pre = bench("partition/dp 64-stage prefix", dw, di, || {
        std::hint::black_box(dp_optimal_prefix(&rc, &cl, &cuts, dp_micro, None).unwrap());
    });
    let dp_mono = bench("partition/dp 64-stage prefix+monotone", dw, di, || {
        std::hint::black_box(dp_optimal_rc(&rc, &cl, &cuts, dp_micro, None).unwrap());
    });
    let dp_speedup = dp_ref.p50 / dp_mono.p50;
    println!(
        "  dp_partition speedup (reference/monotone): {dp_speedup:.1}x  (prefix alone: {:.1}x)",
        dp_ref.p50 / dp_pre.p50
    );
    // Oracle parity, re-checked at bench scale: against the reference
    // triple loop over the *same* prefix tables the partitions must be
    // bit-identical (GNMT's uniform chain ties many equally-optimal
    // partitions exactly, so cross-backing comparisons pin the optimal
    // *value* instead — summation order may break such ties either way).
    let p_ref = dp_optimal_reference(&rc, &cl, &cuts, dp_micro, None).unwrap();
    let p_pre = dp_optimal_prefix(&rc, &cl, &cuts, dp_micro, None).unwrap();
    let p_mono = dp_optimal_rc(&rc, &cl, &cuts, dp_micro, None).unwrap();
    assert_eq!(p_ref.bounds, p_pre.bounds, "prefix DP diverged from the reference scan");
    assert_eq!(p_ref.bounds, p_mono.bounds, "monotone DP diverged from the reference scan");
    let p_seed = dp_optimal_reference(&prof, &cl, &cuts, dp_micro, None).unwrap();
    let t_seed = max_stage_time(&prof, &p_seed, dp_micro, None);
    let t_mono = max_stage_time(&prof, &p_mono, dp_micro, None);
    assert!(
        (t_seed - t_mono).abs() <= 1e-9 * t_seed.max(t_mono),
        "monotone DP lost optimality vs the seed loop: {t_mono} vs {t_seed}"
    );

    // End-to-end exploration (phases A+B, pruning on) at jobs 1 vs 8.
    let e1 = bench("planner/explore 64-stage jobs=1", aw, ai, || {
        std::hint::black_box(planner::explore(&net, &cl, &prof, &mk_opts(1)).epoch_time);
    });
    let e8 = bench("planner/explore 64-stage jobs=8 (permute)", aw, ai, || {
        std::hint::black_box(planner::explore(&net, &cl, &prof, &mk_opts(8)).epoch_time);
    });
    let plan1 = planner::explore(&net, &cl, &prof, &mk_opts(1));
    let plan8 = planner::explore(&net, &cl, &prof, &mk_opts(8));
    assert_eq!(plan1.choice, plan8.choice, "jobs=1 and jobs=8 must select identical plans");
    let (plan_kind, plan_m) = match &plan1.choice {
        Choice::Pipeline { kind, m, .. } => (kind.label().to_string(), *m),
        Choice::DataParallel => ("data-parallel".to_string(), 0),
    };
    println!(
        "  plan: {plan_kind} M={plan_m}; {} simulated, {} pruned of {} candidates",
        plan1.report.simulated_count,
        plan1.report.pruned_count,
        plan1.report.evaluations.len()
    );

    // ---- Device-order neighbourhood search on a heterogeneous
    // 16-device GPU mix (the axis the planner hard-skipped above 8
    // devices): identity alternates V100/P100, so the search has real
    // work — and jobs=1 vs jobs=8 must land on identical plans.
    let het_n = 16usize;
    let het_cl = presets::gpu_mixed_cluster(het_n);
    let het_model = "vgg16";
    let het_net = zoo::by_name(het_model).unwrap();
    let het_prof = analytical::profile(&het_net, &het_cl);
    let het_budget = if quick { 160 } else { 512 };
    let mk_het = |jobs: usize| Options {
        batch_per_device: 8.0,
        samples_per_epoch: 4096,
        consider_dp: false,
        permute_devices: true,
        order_search: true,
        order_budget: het_budget,
        jobs,
        ..Default::default()
    };
    let os1 = bench("planner/order-search 16-device jobs=1", aw, ai, || {
        std::hint::black_box(
            planner::explore(&het_net, &het_cl, &het_prof, &mk_het(1)).epoch_time,
        );
    });
    let os8 = bench("planner/order-search 16-device jobs=8", aw, ai, || {
        std::hint::black_box(
            planner::explore(&het_net, &het_cl, &het_prof, &mk_het(8)).epoch_time,
        );
    });
    let het_plan = planner::explore(&het_net, &het_cl, &het_prof, &mk_het(1));
    let het_plan8 = planner::explore(&het_net, &het_cl, &het_prof, &mk_het(8));
    assert_eq!(het_plan.choice, het_plan8.choice, "order search must be jobs-independent");
    assert_eq!(het_plan.device_order, het_plan8.device_order);
    let het_identity = planner::explore(
        &het_net,
        &het_cl,
        &het_prof,
        &Options { permute_devices: false, ..mk_het(1) },
    );
    let het_orders =
        het_plan.report.evaluations.iter().map(|e| e.candidate.perm).max().unwrap_or(0) + 1;
    let non_identity = het_plan.device_order != (0..het_n).collect::<Vec<usize>>();
    println!(
        "  order search ({het_n}-device gpu-mixed, budget {het_budget}): epoch {:.1}s vs \
         identity {:.1}s ({} orders evaluated, winner {})",
        het_plan.epoch_time,
        het_identity.epoch_time,
        het_orders,
        if non_identity { "non-identity" } else { "identity" },
    );

    // ---- Pareto-front memory planning on a capacity-halved 8-device
    // V100 cluster: the --pareto/--recompute axes simulate every feasible
    // candidate (time-bound pruning suspended) with per-device peak-byte
    // tracking; report the front and the peak-memory reduction the
    // lightest front plan achieves over the best GPipe candidate.
    let pn = 8usize;
    let pm_model = if quick { "gnmt-l64" } else { "gnmt-l128" };
    let pm_net = zoo::by_name(pm_model).unwrap();
    let mut pm_cl = presets::v100_cluster(pn);
    for d in &mut pm_cl.devices {
        d.mem_capacity /= 2;
    }
    let pm_prof = analytical::profile(&pm_net, &pm_cl);
    let pm_opts = Options {
        batch_per_device: 32.0,
        samples_per_epoch: 4096,
        consider_dp: false,
        pareto: true,
        recompute: true,
        jobs: 8,
        ..Default::default()
    };
    let pm_bench = bench("planner/pareto 8-device halved-capacity", aw, ai, || {
        std::hint::black_box(
            planner::explore(&pm_net, &pm_cl, &pm_prof, &pm_opts).pareto_front.len(),
        );
    });
    let pm_plan = planner::explore(&pm_net, &pm_cl, &pm_prof, &pm_opts);
    let front = &pm_plan.pareto_front;
    assert!(!front.is_empty(), "pareto exploration returned an empty front");
    let lightest = front.last().unwrap();
    // Fastest feasible GPipe candidate's simulated peak — the baseline
    // for the paper-style "memory the balanced schedule saves" row.
    let gpipe_peak = pm_plan
        .report
        .evaluations
        .iter()
        .filter(|e| e.candidate.kind == ScheduleKind::GPipe)
        .filter_map(|e| match &e.outcome {
            Outcome::Evaluated { epoch_time, peak_memory, .. } => {
                peak_memory.iter().copied().max().map(|p| (*epoch_time, p))
            }
            _ => None,
        })
        .min_by(|a, b| a.0.total_cmp(&b.0))
        .map(|(_, p)| p);
    let pm_reduction = gpipe_peak.map(|g| g as f64 / lightest.peak_memory as f64);
    println!(
        "  pareto front ({pm_model}, {pn} halved V100s): {} plans, epoch {:.1}s-{:.1}s, \
         lightest peak {}, vs GPipe {}",
        front.len(),
        front[0].epoch_time,
        lightest.epoch_time,
        bapipe::util::fmt_bytes(lightest.peak_memory),
        pm_reduction.map_or("n/a".to_string(), |r| format!("{r:.2}x smaller")),
    );

    // ---- Elastic replanning on the 16-device GPU mix: a scripted
    // loss/degrade/straggler scenario replayed against the incumbent.
    // Warm path: `elastic::run_scenario` — incumbent-seeded
    // branch-and-bound, seeded order discovery, per-view cache salvage
    // threaded across events. Cold baseline: a from-scratch
    // `planner::explore` of each mutated cluster with the same options.
    let rp_scenario = Scenario::scripted(
        "loss-degrade-straggler",
        vec![
            ClusterEvent::DeviceLoss { device: 3 },
            ClusterEvent::LinkDegrade { link: 0, bandwidth_factor: 0.5, latency_factor: 2.0 },
            ClusterEvent::Straggler { device: 1, slowdown: 1.5 },
        ],
    );
    let rp_warm = bench("planner/replan warm 16-device scenario", aw, ai, || {
        let run = elastic::run_scenario(
            &het_net, &het_cl, &het_prof, &het_plan, &rp_scenario, &mk_het(8),
        )
        .unwrap();
        std::hint::black_box(run.steps.len());
    });
    let rp_cold = bench("planner/replan cold 16-device scenario", aw, ai, || {
        let (mut c, mut p) = (het_cl.clone(), het_prof.clone());
        for ev in &rp_scenario.events {
            let mu = mutate::apply(&het_net, &c, &p, &ev.event).unwrap();
            std::hint::black_box(
                planner::explore(&het_net, &mu.cluster, &mu.profile, &mk_het(8)).epoch_time,
            );
            c = mu.cluster;
            p = mu.profile;
        }
    });
    let rp_run = elastic::run_scenario(
        &het_net, &het_cl, &het_prof, &het_plan, &rp_scenario, &mk_het(8),
    )
    .unwrap();
    let rp_feasible =
        rp_run.steps.iter().all(|s| matches!(s.plan.choice, Choice::Pipeline { .. }));
    let rp_migration_bytes: u64 =
        rp_run.steps.iter().filter_map(|s| s.migration.as_ref().map(|m| m.bytes)).sum();
    let rp_speedup = rp_cold.p50 / rp_warm.p50;
    println!(
        "  replan ({het_n}-device gpu-mixed, {} events): warm {:.0} ms vs cold {:.0} ms \
         ({rp_speedup:.2}x), {} migrated, every event {}",
        rp_scenario.events.len(),
        rp_warm.p50 * 1e3,
        rp_cold.p50 * 1e3,
        bapipe::util::fmt_bytes(rp_migration_bytes),
        if rp_feasible { "feasible" } else { "NOT feasible" },
    );

    // ---- Migration overlap on the same 16-device GPU mix: a straggler
    // makes the planner shift boundaries; the challenger's state
    // transfers are placed into the incumbent's draining bubbles (a 2BW
    // drain keeps an immutable shadow weight version, so mid-drain
    // copies are sound) and compared against the stop-the-world
    // drain-and-copy fallback the same schedule reports.
    let mo_event = ClusterEvent::Straggler { device: 1, slowdown: 1.5 };
    let mo_mu = mutate::apply(&het_net, &het_cl, &het_prof, &mo_event).unwrap();
    let mo_scenario = Scenario::scripted("straggler", vec![mo_event.clone()]);
    let mo_run = elastic::run_scenario(
        &het_net, &het_cl, &het_prof, &het_plan, &mo_scenario, &mk_het(8),
    )
    .unwrap();
    let mo_challenger = &mo_run.steps[0].plan;
    // per-layer physical assignment of a pipeline plan: layer -> the
    // chain slot hosting its stage (straggler mutations keep the device
    // namespace, so old and new share it verbatim)
    let stage_assignment = |plan: &planner::Plan| -> Vec<Option<usize>> {
        match &plan.choice {
            Choice::Pipeline { partition, .. } => {
                let mut a = vec![None; het_net.len()];
                for (s, w) in partition.bounds.windows(2).enumerate() {
                    for l in w[0]..w[1] {
                        a[l] = Some(plan.device_order[s]);
                    }
                }
                a
            }
            Choice::DataParallel => unreachable!("consider_dp is off"),
        }
    };
    let mo_spec = match &het_plan.choice {
        Choice::Pipeline { m, micro, recompute, partition, .. } => {
            let (vcl, vprof) =
                permuted_view(&mo_mu.cluster, &mo_mu.profile, &het_plan.device_order);
            planner::build_spec(
                &vprof, &vcl, partition, ScheduleKind::TwoBW, *recompute, *micro, *m,
            )
        }
        Choice::DataParallel => unreachable!("consider_dp is off"),
    };
    let mo_sched = migrate::schedule_migration(
        &mo_mu.profile,
        &MemoryModel::default(),
        &mo_mu.cluster,
        Some((&mo_spec, het_plan.device_order.as_slice())),
        &stage_assignment(&het_plan),
        &stage_assignment(mo_challenger),
    );
    println!(
        "  migration overlap ({het_n}-device gpu-mixed, {}): {} moved, overlapped stall \
         {:.3} ms vs drain-and-copy {:.3} ms (drain {:.1} ms, weights {} micro-batches stale)",
        mo_event.describe(),
        bapipe::util::fmt_bytes(mo_sched.bytes),
        mo_sched.stall * 1e3,
        mo_sched.drain_stall * 1e3,
        mo_sched.drain_makespan * 1e3,
        mo_sched.stale_weight_mb,
    );

    // ---- Emit the measured trajectory.
    let doc = obj(vec![
        ("bench", Json::from("planner_scale")),
        ("quick", Json::from(quick)),
        (
            "des",
            obj(vec![
                ("schedule", Json::from("1F1B-SO")),
                ("n", Json::from(8usize)),
                ("m", Json::from(256usize)),
                ("total_ops", Json::from(total_ops)),
                ("seed_ns_per_op", Json::Num(seed_ns_per_op)),
                ("fast_ns_per_op", Json::Num(fast_ns_per_op)),
                ("speedup_seed_over_fast", Json::Num(des_speedup)),
            ]),
        ),
        (
            "verify_overhead",
            obj(vec![
                ("schedule", Json::from("1F1B-SO")),
                ("stages", Json::from(vn)),
                ("m", Json::from(vm)),
                ("des_pass_ms", Json::Num(v_des.p50 * 1e3)),
                ("check_ms", Json::Num(v_check.p50 * 1e3)),
                ("ratio_check_over_des", Json::Num(verify_ratio)),
            ]),
        ),
        (
            "sim_batch",
            obj(vec![
                ("schedule", Json::from("1F1B-SO")),
                ("stages", Json::from(bn)),
                ("m_grid", Json::Arr(bm_grid.iter().map(|&m| Json::from(m)).collect())),
                ("fast_ms", Json::Num(sweep_fast.p50 * 1e3)),
                ("batch_ms", Json::Num(sweep_batch.p50 * 1e3)),
                ("speedup_fast_over_batch", Json::Num(batch_speedup)),
            ]),
        ),
        (
            "phase_a",
            obj(vec![
                ("stages", Json::from(stages)),
                ("model", Json::from(model)),
                ("jobs1_ms", Json::Num(pa1.p50 * 1e3)),
                ("jobs8_ms", Json::Num(pa8.p50 * 1e3)),
                ("speedup", Json::Num(pa1.p50 / pa8.p50)),
            ]),
        ),
        (
            "dp_partition",
            obj(vec![
                ("stages", Json::from(stages)),
                ("model", Json::from(model)),
                ("cut_points", Json::from(cuts.len())),
                ("micro", Json::Num(dp_micro)),
                ("reference_ms", Json::Num(dp_ref.p50 * 1e3)),
                ("prefix_ms", Json::Num(dp_pre.p50 * 1e3)),
                ("monotone_ms", Json::Num(dp_mono.p50 * 1e3)),
                ("speedup_reference_over_prefix", Json::Num(dp_ref.p50 / dp_pre.p50)),
                ("speedup_reference_over_monotone", Json::Num(dp_speedup)),
            ]),
        ),
        (
            "order_search",
            obj(vec![
                ("devices", Json::from(het_n)),
                ("model", Json::from(het_model)),
                ("cluster", Json::from(het_cl.describe())),
                ("budget", Json::from(het_budget)),
                ("jobs1_ms", Json::Num(os1.p50 * 1e3)),
                ("jobs8_ms", Json::Num(os8.p50 * 1e3)),
                ("orders_evaluated", Json::from(het_orders)),
                ("epoch_s", Json::Num(het_plan.epoch_time)),
                ("identity_epoch_s", Json::Num(het_identity.epoch_time)),
                (
                    "speedup_over_identity",
                    Json::Num(het_identity.epoch_time / het_plan.epoch_time),
                ),
                ("non_identity_winner", Json::from(non_identity)),
            ]),
        ),
        (
            "pareto_memory",
            obj(vec![
                ("model", Json::from(pm_model)),
                ("devices", Json::from(pn)),
                ("capacity_bytes", Json::Num(pm_cl.devices[0].mem_capacity as f64)),
                ("explore_ms", Json::Num(pm_bench.p50 * 1e3)),
                ("front_size", Json::from(front.len())),
                ("fastest_epoch_s", Json::Num(front[0].epoch_time)),
                ("fastest_peak_bytes", Json::Num(front[0].peak_memory as f64)),
                ("lightest_epoch_s", Json::Num(lightest.epoch_time)),
                ("lightest_peak_bytes", Json::Num(lightest.peak_memory as f64)),
                ("gpipe_peak_bytes", gpipe_peak.map_or(Json::Null, |g| Json::Num(g as f64))),
                ("memory_reduction_vs_gpipe", pm_reduction.map_or(Json::Null, Json::Num)),
                (
                    "memory_scalable_on_front",
                    Json::from(front.iter().any(|p| {
                        p.candidate.kind == ScheduleKind::TwoBW || p.candidate.recompute
                    })),
                ),
            ]),
        ),
        (
            "replan",
            obj(vec![
                ("devices", Json::from(het_n)),
                ("model", Json::from(het_model)),
                ("cluster", Json::from(het_cl.describe())),
                (
                    "scenario",
                    Json::Arr(
                        rp_scenario.events.iter().map(|e| Json::from(e.describe())).collect(),
                    ),
                ),
                ("warm_ms", Json::Num(rp_warm.p50 * 1e3)),
                ("cold_ms", Json::Num(rp_cold.p50 * 1e3)),
                ("speedup_cold_over_warm", Json::Num(rp_speedup)),
                ("migration_bytes", Json::Num(rp_migration_bytes as f64)),
                ("feasible_every_event", Json::from(rp_feasible)),
            ]),
        ),
        (
            "migration_overlap",
            obj(vec![
                ("devices", Json::from(het_n)),
                ("model", Json::from(het_model)),
                ("event", Json::from(mo_event.describe())),
                ("drain_schedule", Json::from(ScheduleKind::TwoBW.label())),
                ("bytes", Json::Num(mo_sched.bytes as f64)),
                ("overlapped", Json::from(mo_sched.overlapped)),
                ("drain_makespan_ms", Json::Num(mo_sched.drain_makespan * 1e3)),
                ("overlapped_stall_ms", Json::Num(mo_sched.stall * 1e3)),
                ("drain_and_copy_stall_ms", Json::Num(mo_sched.drain_stall * 1e3)),
                ("stale_weight_microbatches", Json::from(mo_sched.stale_weight_mb)),
            ]),
        ),
        (
            "explore",
            obj(vec![
                ("stages", Json::from(stages)),
                ("model", Json::from(model)),
                ("m_max", Json::from(*m_grid.last().unwrap())),
                ("jobs1_ms", Json::Num(e1.p50 * 1e3)),
                ("jobs8_ms", Json::Num(e8.p50 * 1e3)),
                ("speedup", Json::Num(e1.p50 / e8.p50)),
                ("plan_kind", Json::from(plan_kind)),
                ("plan_m", Json::from(plan_m)),
                ("simulated", Json::from(plan1.report.simulated_count)),
                ("pruned", Json::from(plan1.report.pruned_count)),
            ]),
        ),
    ]);
    let out = std::env::var("BAPIPE_BENCH_OUT").unwrap_or_else(|_| {
        // `cargo bench` runs from the package root (rust/); the measured
        // trajectory artifact lives at the repository root.
        if std::path::Path::new("../ROADMAP.md").exists() {
            "../BENCH_planner.json".to_string()
        } else {
            "BENCH_planner.json".to_string()
        }
    });
    std::fs::write(&out, doc.to_string_pretty()).expect("write BENCH_planner.json");
    println!("  wrote {out}");

    // The PR's acceptance floor, enforced only after the artifact is on
    // disk (a failed floor must not destroy the measurements needed to
    // diagnose it): the trace-free SoA path must be at least 2x the seed
    // simulator on this shape — it does strictly less work (no nested
    // allocs, no trace, no sort, no quadratic polling). Quick mode (CI
    // smoke on shared runners, 5 iterations) only warns: a noisy-neighbor
    // stall must not fail an unrelated build.
    if des_speedup < 2.0 {
        let msg =
            format!("simulate_fast only {des_speedup:.2}x over the seed simulator (floor: 2x)");
        if quick {
            println!("  WARNING: {msg} — quick mode is noise-prone; run the full bench");
        } else {
            panic!("{msg} (measurements preserved in {out})");
        }
    }

    // This PR's floor, same pattern: on the 64-stage scenario the prefix
    // + monotone DP must be at least 5x the seed triple loop — it does
    // strictly less work (O(1) prefix probes instead of O(L) re-sums,
    // O(log C) crossing searches instead of O(C) scans).
    if dp_speedup < 5.0 {
        let msg = format!(
            "dp_optimal (prefix+monotone) only {dp_speedup:.2}x over the reference loop (floor: 5x)"
        );
        if quick {
            println!("  WARNING: {msg} — quick mode is noise-prone; run the full bench");
        } else {
            panic!("{msg} (measurements preserved in {out})");
        }
    }

    // This PR's floor, same pattern: every scenario event must end with a
    // feasible plan, and the warm-started replan must beat a cold
    // re-exploration of the same mutated clusters — it does strictly less
    // work (incumbent-seeded pruning, salvaged phase-A cache, seeded
    // order portfolio).
    assert!(rp_feasible, "replan scenario left an event without a feasible pipeline");

    // This PR's floor, structural rather than statistical (deterministic
    // model time, so it holds in quick mode too): transfers overlapped
    // into the 2BW drain can never stall longer than drain-and-copy —
    // every slot starts no later than the drain makespan, so it ends no
    // later than makespan + slowest transfer.
    assert!(mo_sched.overlapped, "a 2BW drain must overlap the migration");
    assert!(
        mo_sched.stall <= mo_sched.drain_stall + 1e-12,
        "overlapped stall {} exceeds the drain-and-copy fallback {} \
         (measurements preserved in {out})",
        mo_sched.stall,
        mo_sched.drain_stall
    );
    if rp_speedup < 1.0 {
        let msg = format!(
            "warm replan only {rp_speedup:.2}x over cold re-exploration (floor: 1x)"
        );
        if quick {
            println!("  WARNING: {msg} — quick mode is noise-prone; run the full bench");
        } else {
            panic!("{msg} (measurements preserved in {out})");
        }
    }

    // This PR's floor, same pattern: the static verifier must stay well
    // under the simulation it replaces — at most half of one
    // `simulate_fast` pass on the 64-stage preset. It does strictly less
    // work (one linear walk per stage plus one topological pass; no
    // event ordering, no time arithmetic).
    if verify_ratio > 0.5 {
        let msg = format!(
            "check_program costs {verify_ratio:.2}x of one DES pass (ceiling: 0.5x)"
        );
        if quick {
            println!("  WARNING: {msg} — quick mode is noise-prone; run the full bench");
        } else {
            panic!("{msg} (measurements preserved in {out})");
        }
    }

    // This PR's floor, same pattern: the batched M-grid family sweep must
    // be at least 3x per-candidate simulate_fast at the 1024-stage /
    // M=4096 scale — it does strictly less work (no per-candidate op
    // table or f_done matrix to build and stream, closed-form programs,
    // stage state held in registers across each program burst).
    if batch_speedup < 3.0 {
        let msg = format!(
            "FamilySim::run_grid only {batch_speedup:.2}x over per-candidate simulate_fast \
             (floor: 3x)"
        );
        if quick {
            println!("  WARNING: {msg} — quick mode is noise-prone; run the full bench");
        } else {
            panic!("{msg} (measurements preserved in {out})");
        }
    }
}
