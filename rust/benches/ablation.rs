//! E12 — Ablations over BaPipe's design choices:
//!  * partition algorithm: uniform split vs Eq.1 seed vs seed+refine vs DP-optimal
//!  * micro-batch count M sweep (bubble vs utilization trade)
//!  * communication overlap on/off (SNO vs SO gap vs link speed)
//!  * intra-layer fractional refinement on heterogeneous FPGAs
//!
//! Run: `cargo bench --bench ablation`

use bapipe::cluster::presets;
use bapipe::explorer::{build_spec, evaluate_pipeline, Options};
use bapipe::model::zoo;
use bapipe::partition::{interlayer, intralayer, Partition};
use bapipe::profile::analytical;
use bapipe::schedule::ScheduleKind;
use bapipe::sim::engine::simulate;
use bapipe::util::benchkit::print_table;

fn main() {
    partition_variants();
    m_sweep();
    overlap_vs_link_speed();
    fractional_heterogeneous();
}

fn partition_variants() {
    let mut rows = Vec::new();
    for model in ["vgg16", "gnmt8", "resnet50"] {
        let net = zoo::by_name(model).unwrap();
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let micro = 8.0;
        // uniform split by layer count, snapped to legal cuts
        let l = net.len();
        let mut bounds = vec![0];
        for i in 1..4 {
            let want = i * l / 4;
            let b = cuts
                .iter()
                .map(|&c| c + 1)
                .filter(|&b| b > bounds[i - 1] && b < l)
                .min_by_key(|&b| b.abs_diff(want))
                .unwrap();
            bounds.push(b);
        }
        bounds.push(l);
        bounds.dedup();
        let uniform_t = if bounds.len() == 5 {
            interlayer::max_stage_time(&prof, &Partition::new(bounds, l), micro, None)
        } else {
            f64::NAN
        };
        let seed = interlayer::seed_partition(&prof, &cl, &cuts, micro).unwrap();
        let seed_t = interlayer::max_stage_time(&prof, &seed, micro, None);
        let refined = interlayer::refine(&prof, seed.clone(), &cuts, micro);
        let refined_t = interlayer::max_stage_time(&prof, &refined, micro, None);
        let dp = interlayer::dp_optimal(&prof, &cl, &cuts, micro, None).unwrap();
        let dp_t = interlayer::max_stage_time(&prof, &dp, micro, None);
        rows.push(vec![
            model.to_string(),
            format!("{:.2} ms", uniform_t * 1e3),
            format!("{:.2} ms", seed_t * 1e3),
            format!("{:.2} ms", refined_t * 1e3),
            format!("{:.2} ms", dp_t * 1e3),
            format!("{:.2}x", uniform_t / dp_t),
        ]);
    }
    print_table(
        "Ablation A: max stage time by partition algorithm (4x V100, micro=8)",
        &["model", "uniform", "Eq.1 seed", "seed+refine", "DP-optimal", "uniform/DP"],
        &rows,
    );
}

fn m_sweep() {
    let net = zoo::vgg16(224);
    let cl = presets::v100_cluster(4);
    let prof = analytical::profile(&net, &cl);
    let opts =
        Options { batch_per_device: 32.0, samples_per_epoch: 50_000, ..Default::default() };
    let mut rows = Vec::new();
    for m in [2usize, 4, 8, 16, 32, 64, 128] {
        let r = evaluate_pipeline(&net, &cl, &prof, ScheduleKind::OneFOneBSo, m, &opts);
        rows.push(vec![
            format!("M={m}"),
            match &r {
                Some((mb, _, _)) => format!("{:.1} ms", mb * 1e3),
                None => "infeasible".into(),
            },
            match &r {
                Some((_, ep, _)) => format!("{:.1} s", ep),
                None => "-".into(),
            },
        ]);
    }
    print_table(
        "Ablation B: micro-batch count sweep (VGG-16, 1F1B-SO, 4x V100, B=32)",
        &["M", "mini-batch time", "epoch time"],
        &rows,
    );
    println!("(small M → bubble dominates; large M → micro-batches too small for utilization)");
}

fn overlap_vs_link_speed() {
    let net = zoo::vgg16(224);
    let mut rows = Vec::new();
    for bw_scale in [4.0, 1.0, 0.25] {
        let mut cl = presets::v100_cluster(4);
        for l in &mut cl.links {
            l.bandwidth *= bw_scale;
        }
        let prof = analytical::profile(&net, &cl);
        let m = 32;
        let micro = 4.0;
        let part = interlayer::dp_optimal(&prof, &cl, &net.legal_cuts(), micro, None).unwrap();
        let t = |kind| {
            simulate(&build_spec(&prof, &cl, &part, kind, false, micro, m)).makespan
        };
        let sno = t(ScheduleKind::OneFOneBSno);
        let so = t(ScheduleKind::OneFOneBSo);
        rows.push(vec![
            format!("{:.2} GB/s", 2e9 * bw_scale / 1e9),
            format!("{:.1} ms", sno * 1e3),
            format!("{:.1} ms", so * 1e3),
            format!("{:.2}x", sno / so),
        ]);
    }
    print_table(
        "Ablation C: SO's overlap benefit vs link bandwidth (VGG-16, M=32)",
        &["link BW", "1F1B-SNO", "1F1B-SO", "SNO/SO"],
        &rows,
    );
    println!("(slower links → more non-overlapped comm → bigger SO win)");
}

fn fractional_heterogeneous() {
    let net = zoo::resnet50(224);
    let mut rows = Vec::new();
    for boards in [vec!["VCU118"; 4], vec!["VCU129", "VCU129", "VCU118", "VCU118"]] {
        let cl = presets::fpga_cluster(&boards);
        let prof = analytical::profile(&net, &cl);
        let part = interlayer::dp_optimal(&prof, &cl, &net.legal_cuts(), 1.0, None).unwrap();
        let fp = intralayer::refine_fractional(&prof, &cl, &part, 1.0);
        rows.push(vec![
            cl.describe(),
            format!("{:.2}%", fp.imbalance_before * 100.0),
            format!("{:.2}%", fp.imbalance_after * 100.0),
        ]);
    }
    print_table(
        "Ablation D: intra-layer fractional refinement (ResNet-50 on FPGAs)",
        &["cluster", "imbalance before", "after"],
        &rows,
    );
}
