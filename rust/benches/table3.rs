//! E3 — Table 3: epoch-time comparison among DP, PipeDream, GPipe and
//! BaPipe for VGG-16, ResNet-50 and GNMT-8 on 4 and 8 V100s (analytical
//! V100 profiles + DES). Reports speedups over the DP baseline exactly
//! like the paper's table; absolute times come from our simulated
//! testbed, so *shapes* (who wins, roughly by how much, ResNet → DP)
//! are the reproduction target.
//!
//! Run: `cargo bench --bench table3`

use bapipe::cluster::presets;
use bapipe::model::zoo;
use bapipe::planner::{self, Choice, Options};
use bapipe::profile::analytical;
use bapipe::sim::dp;
use bapipe::util::benchkit::print_table;

fn main() {
    let samples = 50_000usize;
    let mut rows = Vec::new();
    let (mut total_des, mut total_pruned, mut total_cands) = (0usize, 0usize, 0usize);
    for model in ["vgg16", "resnet50", "gnmt8"] {
        let net = zoo::by_name(model).unwrap();
        for n in [4usize, 8] {
            let cl = presets::v100_cluster(n);
            let prof = analytical::profile(&net, &cl);

            // DP at B=32 and B=64 (the paper's two baseline rows).
            let dp32 = dp::minibatch(&prof, &cl, 32.0);
            let dp64 = dp::minibatch(&prof, &cl, 64.0);
            let dp_epoch = |b: f64, fits: bool| {
                if fits {
                    dp::epoch_time(&prof, &cl, b, samples)
                } else {
                    f64::INFINITY
                }
            };
            let e_dp32 = dp_epoch(32.0, dp32.fits);
            let e_dp64 = dp_epoch(64.0, dp64.fits);
            let base = e_dp64.min(e_dp32); // paper's 1x is the best DP config

            // All pipeline frameworks get the same per-device batch the
            // best DP config uses (the paper sets B "as much as possible").
            let opts = Options {
                batch_per_device: 64.0,
                samples_per_epoch: samples,
                jobs: 4,
                ..Default::default()
            };
            let pd = planner::plan_pipedream(&net, &cl, &prof, &opts);
            let gp = planner::plan_gpipe(&net, &cl, &prof, &opts);
            let plan = planner::explore(&net, &cl, &prof, &opts);
            total_des += plan.report.simulated_count;
            total_pruned += plan.report.pruned_count;
            total_cands += plan.report.evaluations.len();

            let speedup = |e: f64| {
                if e.is_finite() {
                    format!("{:.2}x", base / e)
                } else {
                    "OOM".to_string()
                }
            };
            // When the exploration degenerates to DP (the paper's ResNet
            // outcome), every framework runs the DP configuration — the
            // paper reports 1x across the row.
            let degenerate = matches!(plan.choice, Choice::DataParallel);
            let (ba_label, ba_epoch) = match &plan.choice {
                Choice::Pipeline { kind, m, .. } => {
                    (format!("{} M={m}", kind.label()), plan.epoch_time)
                }
                Choice::DataParallel => ("falls back to DP".to_string(), base),
            };
            let pd_cell = if degenerate {
                "1.00x (=DP)".to_string()
            } else {
                pd.map(|(e, b)| format!("{} (B={b})", speedup(e))).unwrap_or("OOM".into())
            };
            let gp_cell = if degenerate {
                "1.00x (=DP)".to_string()
            } else {
                gp.map(|(e, m)| format!("{} (M={m})", speedup(e))).unwrap_or("OOM".into())
            };
            rows.push(vec![
                model.to_string(),
                format!("{n} V100"),
                speedup(e_dp32),
                speedup(e_dp64),
                pd_cell,
                gp_cell,
                format!("{} ({})", speedup(ba_epoch), ba_label),
            ]);
        }
    }
    print_table(
        "Table 3: epoch-time speedup over DP (best-B DP = 1x, as in the paper)",
        &["model", "cluster", "DP B=32", "DP B=64", "PipeDream", "GPipe", "BaPipe"],
        &rows,
    );
    println!(
        "\nPaper shapes to check: BaPipe >= GPipe and >= PipeDream on VGG-16/GNMT;\n\
         every ResNet-50 column ~1x (BaPipe's explorer falls back to DP);\n\
         DP B=32 < DP B=64 (utilization + per-epoch all-reduce count)."
    );
    println!(
        "planner: {total_des} DES runs for {total_cands} candidates ({total_pruned} pruned by \
         analytical bounds)"
    );
}
