//! Micro-benchmarks of the L3 hot paths (the §Perf working set):
//! DES throughput, DP partitioner, explorer, JSON parse, ring all-reduce.
//!
//! Run: `cargo bench --bench micro`

use bapipe::cluster::{presets, ExecMode};
use bapipe::collective::ring::{make_ring, ring_allreduce};
use bapipe::model::zoo;
use bapipe::planner::{self, Options};
use bapipe::partition::interlayer;
use bapipe::profile::{analytical, RangeCost};
use bapipe::schedule::ScheduleKind;
use bapipe::sim::engine::{simulate, simulate_fast, SimArena, SimSpec};
use bapipe::util::benchkit::bench;
use bapipe::util::json::Json;

fn main() {
    // DES: a large schedule (8 stages, 256 micro-batches = 4k+ ops) —
    // the trace-producing path, then the planner's trace-free fast path
    // over a reused arena (see benches/planner_scale.rs for the tracked
    // seed-vs-fast numbers).
    let spec = SimSpec::uniform(ScheduleKind::OneFOneBSo, 8, 256, 1e-3, 2e-3, 0.2e-3, ExecMode::Sync);
    bench("des/1f1b-so n=8 m=256", 3, 20, || {
        std::hint::black_box(simulate(&spec).makespan);
    });
    let mut arena = SimArena::new();
    bench("des/fast 1f1b-so n=8 m=256", 3, 20, || {
        std::hint::black_box(simulate_fast(&spec, &mut arena).makespan);
    });
    let spec_fbp =
        SimSpec::uniform(ScheduleKind::FbpAs, 8, 256, 1e-3, 2e-3, 0.2e-3, ExecMode::Async);
    bench("des/fbp-as n=8 m=256", 3, 20, || {
        std::hint::black_box(simulate(&spec_fbp).makespan);
    });
    bench("des/fast fbp-as n=8 m=256", 3, 20, || {
        std::hint::black_box(simulate_fast(&spec_fbp, &mut arena).makespan);
    });

    // Partitioner: DP-optimal over ResNet-50's 52 layers, 8 stages —
    // the dp_partition trajectory: the seed's O(N·C²·L) reference loop,
    // then the prefix + monotone path `dp_optimal` now runs (table-build
    // included, then amortized over a shared RangeCost as the planner
    // does). 64-stage numbers land in BENCH_planner.json
    // (benches/planner_scale.rs).
    let net = zoo::resnet50(224);
    let cl = presets::v100_cluster(8);
    let prof = analytical::profile(&net, &cl);
    let cuts = net.legal_cuts();
    bench("partition/dp-reference resnet50 n=8", 3, 20, || {
        std::hint::black_box(
            interlayer::dp_optimal_reference(&prof, &cl, &cuts, 4.0, None).unwrap(),
        );
    });
    bench("partition/dp-optimal resnet50 n=8", 3, 20, || {
        std::hint::black_box(
            interlayer::dp_optimal(&prof, &cl, &cuts, 4.0, None).unwrap(),
        );
    });
    let rc = RangeCost::build(&prof);
    bench("partition/dp-optimal(shared tables) resnet50 n=8", 3, 20, || {
        std::hint::black_box(
            interlayer::dp_optimal_rc(&rc, &cl, &cuts, 4.0, None).unwrap(),
        );
    });

    // Whole exploration (Fig. 3 flow across schedules and M candidates):
    // exhaustive seed behaviour vs branch-and-bound vs pruned+parallel.
    let vgg = zoo::vgg16(224);
    let vcl = presets::v100_cluster(4);
    let vprof = analytical::profile(&vgg, &vcl);
    let exhaustive = Options {
        batch_per_device: 32.0,
        samples_per_epoch: 50_000,
        prune: false,
        ..Default::default()
    };
    bench("planner/exhaustive vgg16 4xV100", 1, 5, || {
        std::hint::black_box(planner::explore(&vgg, &vcl, &vprof, &exhaustive));
    });
    let pruned = Options { prune: true, ..exhaustive.clone() };
    bench("planner/pruned vgg16 4xV100", 1, 5, || {
        std::hint::black_box(planner::explore(&vgg, &vcl, &vprof, &pruned));
    });
    let parallel = Options { jobs: 8, ..pruned.clone() };
    bench("planner/pruned+jobs=8 vgg16 4xV100", 1, 5, || {
        std::hint::black_box(planner::explore(&vgg, &vcl, &vprof, &parallel));
    });
    let stats = planner::explore(&vgg, &vcl, &vprof, &pruned);
    println!(
        "  (pruned run: {} DES, {} pruned, {} cache hits of {} candidates)",
        stats.report.simulated_count,
        stats.report.pruned_count,
        stats.report.cache_hits,
        stats.report.evaluations.len()
    );

    // JSON parse of a manifest-sized document.
    let doc = {
        let inner: Vec<String> = (0..200)
            .map(|i| format!(r#"{{"name":"p{i}","shape":[{i},128],"x":{i}.5}}"#))
            .collect();
        format!(r#"{{"model":"bench","params":[{}]}}"#, inner.join(","))
    };
    bench("json/parse 200-param manifest", 3, 50, || {
        std::hint::black_box(Json::parse(&doc).unwrap());
    });

    // Ring all-reduce over threads: 4 ranks x 1M floats.
    bench("collective/ring-allreduce 4x1M f32", 1, 5, || {
        let nodes = make_ring(4);
        let handles: Vec<_> = nodes
            .into_iter()
            .map(|node| {
                std::thread::spawn(move || {
                    let mut buf = vec![1.0f32; 1_000_000];
                    ring_allreduce(&node, &mut buf);
                    buf[0]
                })
            })
            .collect();
        for h in handles {
            std::hint::black_box(h.join().unwrap());
        }
    });
}
