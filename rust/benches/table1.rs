//! E1 — Table 1: comparison between 1F1B-AS and FBP-AS (asynchronous
//! scheduling on FPGA clusters). Regenerates the paper's five rows from
//! the closed forms AND cross-checks mini-batch time / memory against the
//! discrete-event simulator.
//!
//! Run: `cargo bench --bench table1`

use bapipe::cluster::ExecMode;
use bapipe::schedule::analytical::*;
use bapipe::schedule::ScheduleKind;
use bapipe::sim::engine::{simulate, SimSpec};
use bapipe::util::benchkit::print_table;

fn main() {
    // The paper's symbolic setting: balanced stages, M micro-batches.
    let cases = [(8usize, 3usize), (16, 4), (64, 4), (128, 8)];
    let (f, b, sr) = (1.0e-3, 2.0e-3, 0.25e-3);
    let a = 4.0e6; // activation bytes per micro-batch at a boundary
    let w = 16.0e6;

    let mut rows = Vec::new();
    for (m, n) in cases {
        let s = Symbols { m, n, f, b, sr, a, w };
        for kind in [ScheduleKind::OneFOneBAs, ScheduleKind::FbpAs] {
            let t = minibatch_time(kind, &s);
            let bubble = bubble_fraction(kind, &s);
            let mem1 = features_memory(kind, &s, 1);
            let wmem = weights_memory(kind, &s, 1);
            let bw = demand_bandwidth(kind, &s);
            // DES cross-check (comm fully overlapped in the table's setting)
            let spec = SimSpec::uniform(kind, n, m, f, b, sr, ExecMode::Async);
            let des = simulate(&spec);
            rows.push(vec![
                format!("M={m},N={n}"),
                kind.label().to_string(),
                format!("{:.1} ms", t * 1e3),
                format!("{:.1} ms", des.makespan * 1e3),
                format!("{:.1}%", bubble * 100.0),
                format!("{:.1} MB", mem1 / 1e6),
                format!("{}x", des.peak_in_flight[0]),
                format!("{:.0} MB", wmem / 1e6),
                format!("{:.1} GB/s", bw / 1e9),
            ]);
        }
    }
    print_table(
        "Table 1: 1F1B-AS vs FBP-AS (paper closed forms + DES cross-check)",
        &[
            "case", "schedule", "mini-batch(paper)", "mini-batch(DES)", "bubble",
            "feat mem@stage1", "DES in-flight@1", "weights mem", "demand BW",
        ],
        &rows,
    );
    println!(
        "\nShape checks: equal time & bubble; FBP 2x feature memory; FBP lower demand\n\
         bandwidth (2a/(F+B) vs a/F with B=2F). DES FBP depth is (M+2N-1) — the\n\
         static-DSP-partition refinement of the paper's (M+N-1) idealization\n\
         (agrees asymptotically in M; see DESIGN.md)."
    );
}
